//! Data-driven resource topology: the paper's resource graph G_R (§IV,
//! Fig. 3) as a first-class *value* instead of compile-time constants.
//!
//! A [`Topology`] names a set of compute resources (device class, hosting
//! edge device, optional per-enclave EPC parameters, optional speed
//! grade), the network links between hosts (bandwidth / latency), the
//! crypto rate for sealed boundary tensors, and the camera/sink
//! attachment points. Everything downstream — the placement tree, the
//! cost model, the discrete-event simulator, and the deployed pipeline —
//! consumes the graph through this type, so a new evaluation scenario
//! (an N-device cluster, a GPU-rich cloud, heterogeneous enclaves) is a
//! **data file**, not a code change:
//!
//! ```
//! use serdab::topology::Topology;
//!
//! let topo = Topology::paper_testbed();
//! assert_eq!(topo.len(), 5);
//! assert_eq!(topo.name_of(topo.entry()), "TEE1");
//! // JSON round-trip: the schema `serdab plan --topology file.json` loads
//! let json = topo.to_json().to_string_pretty();
//! let back = Topology::from_json(&serdab::util::json::Json::parse(&json).unwrap()).unwrap();
//! assert_eq!(topo, back);
//! ```
//!
//! Resources are referenced by [`ResourceId`] — a dense index into the
//! topology — everywhere a placement, simulator server, or deployment
//! worker needs to say *which* device it means; display names live only
//! here. [`Topology::paper_testbed`] reproduces the paper's evaluation
//! graph (two edge devices, one SGX enclave each, a GPU on E2, untrusted
//! CPUs, a 30 Mbps WAN), byte-identical to the five constants it
//! replaced (`tests/topology_golden.rs` guards that parity).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::profiler::devices::EpcModel;
use crate::profiler::{DeviceKind, ModelProfile};
use crate::util::json::{arr, num, obj, s, Json};

/// Index of a resource within its [`Topology`] (dense, 0-based).
///
/// Placements, simulator servers, and deployment workers all refer to
/// resources by id; names and device parameters are resolved through the
/// topology the id indexes into.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ResourceId(pub usize);

impl ResourceId {
    /// The raw index into [`Topology::resources`].
    pub fn index(self) -> usize {
        self.0
    }
}

/// One compute resource in the graph: a device class pinned to a host,
/// with optional per-resource cost overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct ResourceSpec {
    /// Display name, unique within the topology (e.g. `"TEE1"`).
    pub name: String,
    /// Device class (TEE / GPU / untrusted CPU).
    pub kind: DeviceKind,
    /// Which edge device hosts it (0-based). Transfers between different
    /// hosts pay the link cost; intra-host handoffs do not.
    pub host: usize,
    /// Speed grade relative to the profiled device class (block times are
    /// divided by this; 1.0 = the profile's reference hardware). Lets one
    /// topology mix e.g. a weak edge GPU and a fast cloud GPU.
    pub speed: f64,
    /// Fixed seconds charged per *invocation* of a stage on this resource
    /// (enclave ecall/ocall transitions, kernel launch, record dispatch) —
    /// independent of how many frames the invocation carries. Micro-
    /// batching amortizes it: a batch-`B` call pays it once instead of
    /// `B` times (see `placement::cost::PathCost::stage_secs_batched`).
    /// Default 0.0, which keeps every cost identical to the pre-batching
    /// model.
    pub invoke_overhead_secs: f64,
    /// Per-enclave EPC capacity/paging override (TEEs only). `None` uses
    /// the model profile's EPC parameters.
    pub epc: Option<EpcModel>,
}

impl ResourceSpec {
    /// A resource with default cost parameters (speed 1.0, profile EPC).
    pub fn new(name: impl Into<String>, kind: DeviceKind, host: usize) -> Self {
        ResourceSpec {
            name: name.into(),
            kind,
            host,
            speed: 1.0,
            invoke_overhead_secs: 0.0,
            epc: None,
        }
    }
}

/// Point-to-point network parameters of one host-pair link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkParams {
    /// Link bandwidth in bits/second.
    pub bandwidth_bps: f64,
    /// One-way latency added to every transfer.
    pub rtt_secs: f64,
}

impl Default for LinkParams {
    /// The paper's controlled WAN: 30 Mbit/s, 10 ms latency.
    fn default() -> Self {
        LinkParams { bandwidth_bps: 30e6, rtt_secs: 10e-3 }
    }
}

impl LinkParams {
    /// tr(E_a --D--> E_b) = D/B + fixed latency (paper §IV).
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 / self.bandwidth_bps + self.rtt_secs
    }
}

/// AES-GCM seal/open throughput used for boundary tensors crossing a
/// trust boundary (bytes/second; the default matches the measured class
/// value the paper reports — see `crypto::gcm` for the real thing).
pub const DEFAULT_CRYPTO_BYTES_PER_SEC: f64 = 400e6;

/// A named resource graph: resources, links, crypto rate, and the
/// camera/sink attachment points. Construct via [`Topology::builder`],
/// [`Topology::paper_testbed`], or [`Topology::load`] (JSON).
#[derive(Debug, Clone, PartialEq)]
pub struct Topology {
    /// Topology display name (e.g. `"paper-testbed"`).
    pub name: String,
    resources: Vec<ResourceSpec>,
    /// Link parameters for host pairs without an explicit entry.
    pub default_link: LinkParams,
    links: BTreeMap<(usize, usize), LinkParams>,
    /// Seal+open throughput for boundary tensors (bytes/second).
    pub crypto_bytes_per_sec: f64,
    /// Host the camera (frame source) attaches to.
    pub camera_host: usize,
    /// Host the result sink attaches to.
    pub sink_host: usize,
}

impl Topology {
    /// Start building a topology with the given name.
    pub fn builder(name: impl Into<String>) -> TopologyBuilder {
        TopologyBuilder {
            name: name.into(),
            resources: Vec::new(),
            default_link: LinkParams::default(),
            no_default_link: false,
            links: Vec::new(),
            crypto_bytes_per_sec: DEFAULT_CRYPTO_BYTES_PER_SEC,
            camera_host: 0,
            sink_host: 0,
        }
    }

    /// The paper's evaluation testbed: two edge devices, one enclave
    /// each, a GPU on E2, untrusted CPUs on both, 30 Mbps WAN, camera and
    /// sink on E1. Reproduces the five-resource graph the solver was
    /// originally hardcoded to.
    pub fn paper_testbed() -> Topology {
        Topology::builder("paper-testbed")
            .resource("TEE1", DeviceKind::Tee, 0)
            .resource("TEE2", DeviceKind::Tee, 1)
            .resource("E1", DeviceKind::UntrustedCpu, 0)
            .resource("E2", DeviceKind::UntrustedCpu, 1)
            .resource("GPU2", DeviceKind::Gpu, 1)
            .camera(0)
            .sink(0)
            .build()
            .expect("paper testbed is a valid topology")
    }

    // ---- graph accessors -------------------------------------------------

    /// All resources, in declaration order (the order ids index).
    pub fn resources(&self) -> &[ResourceSpec] {
        &self.resources
    }

    /// Number of resources.
    pub fn len(&self) -> usize {
        self.resources.len()
    }

    /// Whether the topology has no resources (never true for a built
    /// topology — construction requires at least one enclave).
    pub fn is_empty(&self) -> bool {
        self.resources.is_empty()
    }

    /// All resource ids, in declaration order.
    pub fn ids(&self) -> Vec<ResourceId> {
        (0..self.resources.len()).map(ResourceId).collect()
    }

    /// The spec of a resource (panics on an id from another topology).
    pub fn resource(&self, id: ResourceId) -> &ResourceSpec {
        &self.resources[id.0]
    }

    /// The spec of a resource, `None` if the id is out of range.
    pub fn get(&self, id: ResourceId) -> Option<&ResourceSpec> {
        self.resources.get(id.0)
    }

    /// Look up a resource id by name.
    pub fn id(&self, name: &str) -> Option<ResourceId> {
        self.resources.iter().position(|r| r.name == name).map(ResourceId)
    }

    /// Look up a resource id by name, erroring with the available names.
    pub fn require(&self, name: &str) -> Result<ResourceId> {
        self.id(name).ok_or_else(|| {
            anyhow!(
                "no resource '{name}' in topology '{}' (have: {:?})",
                self.name,
                self.resources.iter().map(|r| r.name.as_str()).collect::<Vec<_>>()
            )
        })
    }

    /// Display name of a resource.
    pub fn name_of(&self, id: ResourceId) -> &str {
        &self.resources[id.0].name
    }

    /// Host index of a resource.
    pub fn host_of(&self, id: ResourceId) -> usize {
        self.resources[id.0].host
    }

    /// Device class of a resource.
    pub fn kind_of(&self, id: ResourceId) -> DeviceKind {
        self.resources[id.0].kind
    }

    /// Number of hosts (max host index + 1).
    pub fn hosts(&self) -> usize {
        self.resources.iter().map(|r| r.host + 1).max().unwrap_or(0)
    }

    /// Human-readable label for a host: the name of the host's untrusted
    /// CPU resource when it has exactly one (the resource that *is* the
    /// edge device in the paper graph — `E1`, `E2`), otherwise `host{h}`.
    /// Used to label cross-host link workers (`E1→E2`) so deployment
    /// reports and monitor output name the actual edge devices.
    pub fn host_label(&self, host: usize) -> String {
        let mut cpus = self
            .resources
            .iter()
            .filter(|r| r.host == host && r.kind == DeviceKind::UntrustedCpu);
        match (cpus.next(), cpus.next()) {
            (Some(r), None) => r.name.clone(),
            _ => format!("host{host}"),
        }
    }

    /// Display label for the directed link a placement hop crosses,
    /// e.g. `E1→E2`.
    pub fn link_label(&self, from_host: usize, to_host: usize) -> String {
        format!("{}→{}", self.host_label(from_host), self.host_label(to_host))
    }

    /// Trusted enclaves, in declaration order.
    pub fn tees(&self) -> Vec<ResourceId> {
        self.of_kind(|k| k == DeviceKind::Tee)
    }

    /// GPUs, in declaration order.
    pub fn gpus(&self) -> Vec<ResourceId> {
        self.of_kind(|k| k == DeviceKind::Gpu)
    }

    /// Untrusted resources (CPUs and GPUs), in declaration order.
    pub fn untrusted(&self) -> Vec<ResourceId> {
        self.of_kind(|k| !k.trusted())
    }

    fn of_kind(&self, pred: impl Fn(DeviceKind) -> bool) -> Vec<ResourceId> {
        self.resources
            .iter()
            .enumerate()
            .filter(|(_, r)| pred(r.kind))
            .map(|(i, _)| ResourceId(i))
            .collect()
    }

    /// Where processing starts: the first enclave on the camera host, or
    /// the first enclave overall (the paper's "processing starts in
    /// TEE₁, the trusted source side"). Valid topologies always have at
    /// least one TEE, so this never fails.
    pub fn entry(&self) -> ResourceId {
        let tees = self.tees();
        for &t in &tees {
            if self.host_of(t) == self.camera_host {
                return t;
            }
        }
        tees[0]
    }

    /// One-line summary for logs: name, resource/TEE/host counts.
    pub fn summary(&self) -> String {
        format!(
            "{} ({} resources, {} TEEs, {} hosts)",
            self.name,
            self.len(),
            self.tees().len(),
            self.hosts()
        )
    }

    // ---- network ---------------------------------------------------------

    /// Link parameters between two hosts (order-insensitive; falls back
    /// to [`Topology::default_link`] for pairs without an explicit entry).
    pub fn link(&self, a: usize, b: usize) -> LinkParams {
        let key = (a.min(b), a.max(b));
        self.links.get(&key).copied().unwrap_or(self.default_link)
    }

    /// Set (or override) the link parameters of one host pair.
    pub fn set_link(&mut self, a: usize, b: usize, params: LinkParams) {
        self.links.insert((a.min(b), a.max(b)), params);
    }

    /// Speed grade of a resource (block times are divided by this).
    pub fn speed_of(&self, id: ResourceId) -> f64 {
        self.resources[id.0].speed
    }

    /// Re-grade a resource's speed. This is how online re-partitioning
    /// folds *observed* stage times back into the planning inputs: if a
    /// stage measured ρ× slower than predicted, dividing its resource's
    /// speed by ρ makes every subsequent solve charge the observed rate
    /// (see [`placement::cost::recalibrate_speeds`](crate::placement::cost::recalibrate_speeds)).
    pub fn set_speed(&mut self, id: ResourceId, speed: f64) {
        assert!(speed > 0.0, "speed grade must be positive");
        self.resources[id.0].speed = speed;
    }

    /// Fixed per-invocation seconds of a resource (0.0 unless declared).
    pub fn invoke_overhead_of(&self, id: ResourceId) -> f64 {
        self.resources[id.0].invoke_overhead_secs
    }

    /// Set a resource's fixed per-invocation overhead.
    pub fn set_invoke_overhead(&mut self, id: ResourceId, secs: f64) {
        assert!(secs.is_finite() && secs >= 0.0, "invoke overhead must be non-negative");
        self.resources[id.0].invoke_overhead_secs = secs;
    }

    /// Transfer seconds for `bytes` between two hosts (0 for intra-host).
    pub fn transfer_secs(&self, a: usize, b: usize, bytes: u64) -> f64 {
        if a == b {
            0.0
        } else {
            self.link(a, b).transfer_secs(bytes)
        }
    }

    /// Encrypt + decrypt cost for a boundary tensor crossing a trust
    /// boundary.
    pub fn crypto_secs(&self, bytes: u64) -> f64 {
        2.0 * bytes as f64 / self.crypto_bytes_per_sec
    }

    /// Replace the topology's assumed seal/open throughput with a
    /// measured one (e.g. `crypto::gcm::measured_rate()` on the machine
    /// the pipeline will run on), so the cost model charges sealed hops
    /// what this hardware actually pays. Non-finite or non-positive
    /// rates are ignored — the calibrated default survives a failed
    /// measurement.
    pub fn calibrate_crypto_rate(&mut self, bytes_per_sec: f64) {
        if bytes_per_sec.is_finite() && bytes_per_sec > 0.0 {
            self.crypto_bytes_per_sec = bytes_per_sec;
        }
    }

    // ---- per-resource cost -----------------------------------------------

    /// Execution seconds of a contiguous block `range` on resource `id`
    /// under `prof`: the profile's per-class block times scaled by the
    /// resource's speed grade, plus the enclave paging penalty for TEEs
    /// (using the resource's EPC override when present — how a topology
    /// expresses heterogeneous enclaves).
    pub fn stage_secs(
        &self,
        prof: &ModelProfile,
        id: ResourceId,
        range: std::ops::Range<usize>,
    ) -> f64 {
        let spec = &self.resources[id.0];
        let base: f64 =
            prof.device(spec.kind).block_secs[range.clone()].iter().sum::<f64>() / spec.speed;
        match spec.kind {
            DeviceKind::Tee => base + self.paging_secs(prof, id, range),
            _ => base,
        }
    }

    /// Extra seconds per frame spent paging EPC for enclave `id` running
    /// `range` (0 for non-TEE resources).
    pub fn paging_secs(
        &self,
        prof: &ModelProfile,
        id: ResourceId,
        range: std::ops::Range<usize>,
    ) -> f64 {
        let spec = &self.resources[id.0];
        if spec.kind != DeviceKind::Tee {
            return 0.0;
        }
        prof.paging_secs_with(spec.epc.as_ref().unwrap_or(&prof.epc), range)
    }

    // ---- JSON ------------------------------------------------------------

    /// Serialize to the topology JSON schema (see DESIGN.md §12).
    pub fn to_json(&self) -> Json {
        let resources = self
            .resources
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("name", s(r.name.clone())),
                    ("kind", s(r.kind.name())),
                    ("host", num(r.host as f64)),
                ];
                if (r.speed - 1.0).abs() > 1e-12 {
                    fields.push(("speed", num(r.speed)));
                }
                if r.invoke_overhead_secs > 0.0 {
                    fields.push(("invoke_overhead_secs", num(r.invoke_overhead_secs)));
                }
                if let Some(e) = &r.epc {
                    fields.push(("epc", epc_to_json(e)));
                }
                obj(fields)
            })
            .collect();
        let links = self
            .links
            .iter()
            .map(|(&(a, b), l)| {
                obj(vec![
                    ("a", num(a as f64)),
                    ("b", num(b as f64)),
                    ("bandwidth_bps", num(l.bandwidth_bps)),
                    ("rtt_secs", num(l.rtt_secs)),
                ])
            })
            .collect();
        obj(vec![
            ("name", s(self.name.clone())),
            ("camera_host", num(self.camera_host as f64)),
            ("sink_host", num(self.sink_host as f64)),
            ("crypto_bytes_per_sec", num(self.crypto_bytes_per_sec)),
            (
                "default_link",
                obj(vec![
                    ("bandwidth_bps", num(self.default_link.bandwidth_bps)),
                    ("rtt_secs", num(self.default_link.rtt_secs)),
                ]),
            ),
            ("resources", arr(resources)),
            ("links", arr(links)),
        ])
    }

    /// Parse the topology JSON schema. Link endpoints (`a`/`b`) and the
    /// camera/sink attachment points may be host indices or resource
    /// names (resolved to the resource's host). Rejects malformed graphs:
    /// missing fields, duplicate resource names, unknown hosts/resources,
    /// no enclave, non-positive rates.
    pub fn from_json(j: &Json) -> Result<Topology> {
        let name = match j.get("name") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| anyhow!("topology 'name' must be a string"))?
                .to_string(),
            None => "topology".to_string(),
        };
        for key in j.as_obj().map(|m| m.keys()).into_iter().flatten() {
            match key.as_str() {
                "name" | "camera" | "camera_host" | "sink" | "sink_host"
                | "crypto_bytes_per_sec" | "default_link" | "resources" | "links" => {}
                other => bail!("unknown topology key '{other}'"),
            }
        }
        let mut b = Topology::builder(name);

        let mut default_link = LinkParams::default();
        if let Some(dl) = j.get("default_link") {
            // the string "none" disables the implicit any-to-any fallback:
            // hosts are only connected where links are declared, traffic
            // between non-adjacent hosts is routed over them, and a host
            // with no path to the camera is rejected at build
            if dl.as_str() == Some("none") {
                b = b.no_default_link();
            } else {
                default_link = parse_link_params(dl, LinkParams::default(), false)
                    .context("default_link")?;
                b = b.default_link(default_link);
            }
        }
        if let Some(c) = j.get("crypto_bytes_per_sec") {
            b = b.crypto_rate(
                c.as_f64().ok_or_else(|| anyhow!("crypto_bytes_per_sec must be a number"))?,
            );
        }

        let rs = j
            .req("resources")?
            .as_arr()
            .ok_or_else(|| anyhow!("'resources' must be an array"))?;
        let mut specs: Vec<ResourceSpec> = Vec::new();
        for (i, r) in rs.iter().enumerate() {
            let spec = parse_resource(r).with_context(|| format!("resource [{i}]"))?;
            // duplicate names are also caught by the builder, but here we
            // can say *which entries* collide instead of just the name
            if let Some(prev) = specs.iter().position(|p| p.name == spec.name) {
                bail!(
                    "resource [{i}]: duplicate resource name '{}' (already declared by \
                     resource [{prev}])",
                    spec.name
                );
            }
            specs.push(spec.clone());
            b = b.resource_spec(spec);
        }

        // camera/sink: host index, or a resource name resolved to its host
        let host_ref = |v: &Json, what: &str| -> Result<usize> {
            if let Some(h) = v.as_u64() {
                return Ok(h as usize);
            }
            if let Some(n) = v.as_str() {
                return match specs.iter().find(|r| r.name == n) {
                    Some(r) => Ok(r.host),
                    None => bail!("{what} refers to unknown resource '{n}'"),
                };
            }
            bail!("{what} must be a host index or a resource name")
        };
        if let Some(v) = j.get("camera_host").or_else(|| j.get("camera")) {
            b = b.camera(host_ref(v, "camera attachment")?);
        }
        if let Some(v) = j.get("sink_host").or_else(|| j.get("sink")) {
            b = b.sink(host_ref(v, "sink attachment")?);
        }

        if let Some(ls) = j.get("links") {
            let ls = ls.as_arr().ok_or_else(|| anyhow!("'links' must be an array"))?;
            for (i, l) in ls.iter().enumerate() {
                let a = host_ref(l.req("a")?, "link endpoint 'a'")
                    .with_context(|| format!("link [{i}]"))?;
                let bb = host_ref(l.req("b")?, "link endpoint 'b'")
                    .with_context(|| format!("link [{i}]"))?;
                // unspecified link fields inherit the file's default link,
                // not the hardcoded paper constants
                let params = parse_link_params(l, default_link, true)
                    .with_context(|| format!("link [{i}]"))?;
                b = b.link(a, bb, params);
            }
        }
        b.build()
    }

    /// Load a topology from a JSON file.
    pub fn load(path: impl AsRef<Path>) -> Result<Topology> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading topology file {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Topology::from_json(&j).with_context(|| format!("topology file {}", path.display()))
    }

    /// Write the topology to a JSON file (pretty-printed).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .with_context(|| format!("writing topology file {}", path.display()))
    }
}

fn parse_resource(r: &Json) -> Result<ResourceSpec> {
    let o = r.as_obj().ok_or_else(|| anyhow!("resource must be an object"))?;
    for key in o.keys() {
        match key.as_str() {
            "name" | "kind" | "host" | "speed" | "invoke_overhead_secs" | "epc" => {}
            other => bail!(
                "unknown resource key '{other}' (name|kind|host|speed|invoke_overhead_secs|epc)"
            ),
        }
    }
    let name = r
        .req("name")?
        .as_str()
        .ok_or_else(|| anyhow!("resource 'name' must be a string"))?
        .to_string();
    let kind_txt = r
        .req("kind")?
        .as_str()
        .ok_or_else(|| anyhow!("resource 'kind' must be a string"))?;
    let kind = match kind_txt {
        "tee" => DeviceKind::Tee,
        "cpu" => DeviceKind::UntrustedCpu,
        "gpu" => DeviceKind::Gpu,
        other => bail!("unknown device kind '{other}' (tee|cpu|gpu)"),
    };
    let host = r
        .req("host")?
        .as_u64()
        .ok_or_else(|| anyhow!("resource 'host' must be a non-negative integer"))?
        as usize;
    let mut spec = ResourceSpec::new(name, kind, host);
    if let Some(v) = r.get("speed") {
        spec.speed = v.as_f64().ok_or_else(|| anyhow!("resource 'speed' must be a number"))?;
    }
    if let Some(v) = r.get("invoke_overhead_secs") {
        spec.invoke_overhead_secs = v
            .as_f64()
            .ok_or_else(|| anyhow!("resource 'invoke_overhead_secs' must be a number"))?;
    }
    if let Some(e) = r.get("epc") {
        spec.epc = Some(epc_from_json(e)?);
    }
    Ok(spec)
}

/// Accepts raw units (`bandwidth_bps` / `rtt_secs` — what [`Topology::to_json`]
/// emits, exact round-trip) or human units (`bandwidth_mbps` / `rtt_ms` —
/// convenient in hand-written files). Fields left unspecified keep `base`;
/// unknown keys are rejected so a typo'd field cannot silently fall back.
fn parse_link_params(j: &Json, base: LinkParams, allow_endpoints: bool) -> Result<LinkParams> {
    let o = j.as_obj().ok_or_else(|| anyhow!("link parameters must be an object"))?;
    for key in o.keys() {
        match key.as_str() {
            "bandwidth_bps" | "bandwidth_mbps" | "rtt_secs" | "rtt_ms" => {}
            "a" | "b" if allow_endpoints => {}
            other => bail!(
                "unknown link key '{other}' (bandwidth_bps|bandwidth_mbps|rtt_secs|rtt_ms)"
            ),
        }
    }
    let mut p = base;
    if let Some(v) = j.get("bandwidth_bps") {
        p.bandwidth_bps = v.as_f64().ok_or_else(|| anyhow!("'bandwidth_bps' must be a number"))?;
    } else if let Some(v) = j.get("bandwidth_mbps") {
        p.bandwidth_bps =
            v.as_f64().ok_or_else(|| anyhow!("'bandwidth_mbps' must be a number"))? * 1e6;
    }
    if let Some(v) = j.get("rtt_secs") {
        p.rtt_secs = v.as_f64().ok_or_else(|| anyhow!("'rtt_secs' must be a number"))?;
    } else if let Some(v) = j.get("rtt_ms") {
        p.rtt_secs = v.as_f64().ok_or_else(|| anyhow!("'rtt_ms' must be a number"))? * 1e-3;
    }
    Ok(p)
}

fn epc_to_json(e: &EpcModel) -> Json {
    obj(vec![
        ("epc_bytes", num(e.epc_bytes as f64)),
        ("runtime_bytes", num(e.runtime_bytes as f64)),
        ("act_factor", num(e.act_factor)),
        ("page_secs_per_byte", num(e.page_secs_per_byte)),
    ])
}

fn epc_from_json(j: &Json) -> Result<EpcModel> {
    let o = j.as_obj().ok_or_else(|| anyhow!("'epc' must be an object"))?;
    for key in o.keys() {
        match key.as_str() {
            "epc_bytes" | "runtime_bytes" | "act_factor" | "page_secs_per_byte" => {}
            other => bail!(
                "unknown epc key '{other}' (epc_bytes|runtime_bytes|act_factor|page_secs_per_byte)"
            ),
        }
    }
    let mut e = EpcModel::default();
    if let Some(v) = j.get("epc_bytes") {
        e.epc_bytes = v.as_u64().ok_or_else(|| anyhow!("'epc_bytes' must be an integer"))?;
    }
    if let Some(v) = j.get("runtime_bytes") {
        e.runtime_bytes =
            v.as_u64().ok_or_else(|| anyhow!("'runtime_bytes' must be an integer"))?;
    }
    if let Some(v) = j.get("act_factor") {
        e.act_factor = v.as_f64().ok_or_else(|| anyhow!("'act_factor' must be a number"))?;
    }
    if let Some(v) = j.get("page_secs_per_byte") {
        e.page_secs_per_byte =
            v.as_f64().ok_or_else(|| anyhow!("'page_secs_per_byte' must be a number"))?;
    }
    Ok(e)
}

/// Builder for [`Topology`] — chain resource/link/attachment calls, then
/// [`TopologyBuilder::build`] validates the whole graph.
pub struct TopologyBuilder {
    name: String,
    resources: Vec<ResourceSpec>,
    default_link: LinkParams,
    no_default_link: bool,
    links: Vec<(usize, usize, LinkParams)>,
    crypto_bytes_per_sec: f64,
    camera_host: usize,
    sink_host: usize,
}

impl TopologyBuilder {
    /// Add a resource with default cost parameters.
    pub fn resource(self, name: impl Into<String>, kind: DeviceKind, host: usize) -> Self {
        self.resource_spec(ResourceSpec::new(name, kind, host))
    }

    /// Add a fully-specified resource (speed grade / EPC override).
    pub fn resource_spec(mut self, spec: ResourceSpec) -> Self {
        self.resources.push(spec);
        self
    }

    /// Set explicit link parameters between two hosts.
    pub fn link(mut self, a: usize, b: usize, params: LinkParams) -> Self {
        self.links.push((a, b, params));
        self
    }

    /// Set the fallback link parameters for host pairs without an entry.
    pub fn default_link(mut self, params: LinkParams) -> Self {
        self.default_link = params;
        self.no_default_link = false;
        self
    }

    /// Disable the implicit any-to-any fallback link (the JSON schema's
    /// `"default_link": "none"`). Hosts are then only connected where
    /// links were declared: [`TopologyBuilder::build`] routes every other
    /// host pair over the declared graph (bottleneck bandwidth, summed
    /// rtt, path minimizing the store-and-forward time of a 1 MB
    /// reference tensor) and materializes the result, and rejects the
    /// topology if any resource's host has no path to the camera host.
    pub fn no_default_link(mut self) -> Self {
        self.no_default_link = true;
        self
    }

    /// Set the seal+open crypto throughput (bytes/second).
    pub fn crypto_rate(mut self, bytes_per_sec: f64) -> Self {
        self.crypto_bytes_per_sec = bytes_per_sec;
        self
    }

    /// Attach the camera (frame source) to a host.
    pub fn camera(mut self, host: usize) -> Self {
        self.camera_host = host;
        self
    }

    /// Attach the result sink to a host.
    pub fn sink(mut self, host: usize) -> Self {
        self.sink_host = host;
        self
    }

    /// Validate and build the topology.
    ///
    /// Rejected graphs: no resources, duplicate/empty resource names, no
    /// enclave (processing must be able to start in a TEE), non-positive
    /// speed/bandwidth/crypto rates, camera/sink/link endpoints naming a
    /// host no resource lives on.
    pub fn build(self) -> Result<Topology> {
        if self.resources.is_empty() {
            bail!("topology '{}' has no resources", self.name);
        }
        let mut seen = std::collections::BTreeSet::new();
        for r in &self.resources {
            if r.name.is_empty() {
                bail!("topology '{}' has a resource with an empty name", self.name);
            }
            if !seen.insert(r.name.clone()) {
                bail!("duplicate resource name '{}'", r.name);
            }
            if !(r.speed.is_finite() && r.speed > 0.0) {
                bail!("resource '{}' has non-positive speed {}", r.name, r.speed);
            }
            if !(r.invoke_overhead_secs.is_finite() && r.invoke_overhead_secs >= 0.0) {
                bail!(
                    "resource '{}' has negative invoke overhead {}",
                    r.name,
                    r.invoke_overhead_secs
                );
            }
        }
        if !self.resources.iter().any(|r| r.kind == DeviceKind::Tee) {
            bail!("topology '{}' has no enclave (need at least one tee resource)", self.name);
        }
        // attachment points and links must name hosts some resource lives
        // on — a host index inside a numbering gap is almost certainly a
        // typo, so reject it instead of planning against a ghost host
        let occupied: std::collections::BTreeSet<usize> =
            self.resources.iter().map(|r| r.host).collect();
        if !occupied.contains(&self.camera_host) {
            bail!("camera host {} does not exist (no resource lives there)", self.camera_host);
        }
        if !occupied.contains(&self.sink_host) {
            bail!("sink host {} does not exist (no resource lives there)", self.sink_host);
        }
        if !(self.crypto_bytes_per_sec.is_finite() && self.crypto_bytes_per_sec > 0.0) {
            bail!("crypto_bytes_per_sec must be positive");
        }
        let check_link = |p: &LinkParams| -> Result<()> {
            if !(p.bandwidth_bps.is_finite() && p.bandwidth_bps > 0.0) {
                bail!("link bandwidth must be positive");
            }
            if !(p.rtt_secs.is_finite() && p.rtt_secs >= 0.0) {
                bail!("link rtt must be non-negative");
            }
            Ok(())
        };
        check_link(&self.default_link)?;
        let mut links = BTreeMap::new();
        for (a, b, p) in self.links {
            if !occupied.contains(&a) || !occupied.contains(&b) {
                bail!("link ({a}, {b}) references a host that does not exist");
            }
            if a == b {
                bail!("link ({a}, {b}) connects a host to itself");
            }
            check_link(&p)?;
            links.insert((a.min(b), a.max(b)), p);
        }
        if self.no_default_link {
            links =
                route_links(&self.name, &occupied, &links, self.camera_host, &self.resources)?;
        }
        Ok(Topology {
            name: self.name,
            resources: self.resources,
            default_link: self.default_link,
            links,
            crypto_bytes_per_sec: self.crypto_bytes_per_sec,
            camera_host: self.camera_host,
            sink_host: self.sink_host,
        })
    }
}

/// Reference payload for route selection under `"default_link": "none"`:
/// paths are ranked by the summed per-edge store-and-forward time of a
/// 1 MB boundary tensor, which weighs bandwidth and rtt the way the cost
/// model's boundary terms do.
const ROUTE_REF_BYTES: u64 = 1_000_000;

/// Route every occupied host pair over the declared links (Floyd–Warshall
/// on the additive reference-transfer cost, tracking the path's
/// bottleneck bandwidth and summed rtt) and materialize the effective
/// [`LinkParams`] so [`Topology::link`] works unchanged downstream.
/// Rejects the graph — naming the stranded resources — when a host has
/// no path to the camera host.
fn route_links(
    name: &str,
    occupied: &std::collections::BTreeSet<usize>,
    links: &BTreeMap<(usize, usize), LinkParams>,
    camera_host: usize,
    resources: &[ResourceSpec],
) -> Result<BTreeMap<(usize, usize), LinkParams>> {
    let hosts: Vec<usize> = occupied.iter().copied().collect();
    let idx: BTreeMap<usize, usize> = hosts.iter().enumerate().map(|(i, &h)| (h, i)).collect();
    let n = hosts.len();
    // dist[i][j] = (ref cost, bottleneck bandwidth, summed rtt)
    let mut dist: Vec<Vec<Option<(f64, f64, f64)>>> = vec![vec![None; n]; n];
    for (i, row) in dist.iter_mut().enumerate() {
        row[i] = Some((0.0, f64::INFINITY, 0.0));
    }
    for (&(a, b), p) in links {
        let (i, j) = (idx[&a], idx[&b]);
        let edge = Some((p.transfer_secs(ROUTE_REF_BYTES), p.bandwidth_bps, p.rtt_secs));
        dist[i][j] = edge;
        dist[j][i] = edge;
    }
    for k in 0..n {
        for i in 0..n {
            let Some((cik, bik, rik)) = dist[i][k] else { continue };
            for j in 0..n {
                let Some((ckj, bkj, rkj)) = dist[k][j] else { continue };
                let cand = cik + ckj;
                if dist[i][j].is_none_or(|(c, _, _)| cand < c) {
                    dist[i][j] = Some((cand, bik.min(bkj), rik + rkj));
                }
            }
        }
    }
    let cam = idx[&camera_host];
    let mut stranded: Vec<String> = Vec::new();
    for (j, &h) in hosts.iter().enumerate() {
        if dist[cam][j].is_none() {
            stranded.extend(
                resources.iter().filter(|r| r.host == h).map(|r| format!("'{}'", r.name)),
            );
        }
    }
    if !stranded.is_empty() {
        bail!(
            "topology '{name}': default_link is \"none\" and {} unreachable from camera \
             host {camera_host} over the declared links: {}",
            if stranded.len() == 1 { "this resource is" } else { "these resources are" },
            stranded.join(", ")
        );
    }
    let mut out = links.clone();
    for i in 0..n {
        for j in i + 1..n {
            let key = (hosts[i].min(hosts[j]), hosts[i].max(hosts[j]));
            if out.contains_key(&key) {
                continue;
            }
            // camera-connectivity on an undirected graph implies pairwise
            // connectivity, so this entry always exists
            if let Some((_, bw, rtt)) = dist[i][j] {
                out.insert(key, LinkParams { bandwidth_bps: bw, rtt_secs: rtt });
            }
        }
    }
    Ok(out)
}

/// Seeded synthetic-fleet generator (`serdab topo gen`): edge→hub→cloud
/// trees and random clusters with heterogeneous speed grades and
/// per-tier links, for exercising the fleet solver
/// ([`placement::fleet`](crate::placement::fleet)) at 64–1024 resources.
/// Deterministic per (kind, resources, seed) — the checked-in
/// `examples/topologies/{tree64,tree256,rand1024}.json` are its outputs.
pub mod gen {
    use super::{DeviceKind, LinkParams, ResourceSpec, Topology};
    use crate::util::rng::Rng;
    use anyhow::{bail, Result};

    /// Topology family to generate.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum GenKind {
        /// Edge→hub→cloud tiers: paired TEE+CPU edge hosts, TEE+CPU hub
        /// hosts, cloud hosts with two fast TEEs and a GPU each, with
        /// per-tier links (edge→hub slow, hub→cloud fast).
        Tree,
        /// Uniformly random device kinds and log-uniform speed grades
        /// scattered over `resources / 4` hosts with random links.
        Random,
    }

    impl GenKind {
        /// Parse a CLI kind name.
        pub fn parse(text: &str) -> Result<GenKind> {
            match text {
                "tree" => Ok(GenKind::Tree),
                "random" | "rand" => Ok(GenKind::Random),
                other => bail!("unknown topology kind '{other}' (tree|random)"),
            }
        }

        /// Lowercase display name.
        pub fn name(self) -> &'static str {
            match self {
                GenKind::Tree => "tree",
                GenKind::Random => "random",
            }
        }
    }

    /// What to generate: family, exact resource count, and seed.
    #[derive(Debug, Clone, Copy)]
    pub struct GenSpec {
        /// Topology family.
        pub kind: GenKind,
        /// Exact number of resources in the output.
        pub resources: usize,
        /// PRNG seed (same spec ⇒ identical topology).
        pub seed: u64,
    }

    /// Round to two decimals: generated grades and millisecond figures
    /// stay short and human-readable in the JSON files.
    fn r2(x: f64) -> f64 {
        (x * 100.0).round() / 100.0
    }

    fn res(name: String, kind: DeviceKind, host: usize, speed: f64) -> ResourceSpec {
        let mut spec = ResourceSpec::new(name, kind, host);
        spec.speed = speed;
        spec
    }

    /// Generate the topology described by `spec`.
    pub fn generate(spec: &GenSpec) -> Result<Topology> {
        match spec.kind {
            GenKind::Tree => gen_tree(spec.resources, spec.seed),
            GenKind::Random => gen_random(spec.resources, spec.seed),
        }
    }

    /// Edge→hub→cloud tree. Tier sizes scale with `n`; at 48+ resources
    /// at least three cloud hosts exist, so host-granular sharding
    /// ([`shard_topology`](crate::coordinator::dispatcher::shard_topology))
    /// can seed three balanced chains with a fast TEE pair each.
    fn gen_tree(n: usize, seed: u64) -> Result<Topology> {
        if n < 2 {
            bail!("tree topologies need at least 2 resources (got {n})");
        }
        let (cloud_hosts, hubs) = if n >= 48 {
            ((n / 20).clamp(3, 8), (n / 16).max(1))
        } else if n >= 12 {
            (2, (n / 16).max(1))
        } else if n >= 8 {
            (1, 1)
        } else {
            (0, 0)
        };
        let edge_res = n - 3 * cloud_hosts - 2 * hubs;
        let edge_hosts = edge_res.div_ceil(2);
        let hub_base = edge_hosts;
        let cloud_base = edge_hosts + hubs;

        let mut rng = Rng::new(seed);
        let mut b = Topology::builder(format!("tree{n}-s{seed}"));
        for e in 0..edge_hosts {
            b = b.resource_spec(res(
                format!("edge{e}-tee"),
                DeviceKind::Tee,
                e,
                r2(rng.range_f64(0.4, 1.0)),
            ));
            if 2 * e + 1 < edge_res {
                b = b.resource_spec(res(
                    format!("edge{e}-cpu"),
                    DeviceKind::UntrustedCpu,
                    e,
                    r2(rng.range_f64(0.3, 0.8)),
                ));
            }
        }
        for k in 0..hubs {
            b = b.resource_spec(res(
                format!("hub{k}-tee"),
                DeviceKind::Tee,
                hub_base + k,
                r2(rng.range_f64(1.2, 2.0)),
            ));
            b = b.resource_spec(res(
                format!("hub{k}-cpu"),
                DeviceKind::UntrustedCpu,
                hub_base + k,
                r2(rng.range_f64(0.8, 1.5)),
            ));
        }
        for c in 0..cloud_hosts {
            for t in 0..2 {
                b = b.resource_spec(res(
                    format!("cloud{c}-tee{t}"),
                    DeviceKind::Tee,
                    cloud_base + c,
                    r2(rng.range_f64(2.0, 4.0)),
                ));
            }
            b = b.resource_spec(res(
                format!("cloud{c}-gpu"),
                DeviceKind::Gpu,
                cloud_base + c,
                r2(rng.range_f64(2.0, 6.0)),
            ));
        }

        // per-tier links; pairs without one (edge↔edge, edge↔cloud) fall
        // back to the builder's default WAN link
        if hubs > 0 {
            for e in 0..edge_hosts {
                b = b.link(
                    e,
                    hub_base + e % hubs,
                    LinkParams {
                        bandwidth_bps: rng.range(30, 101) as f64 * 1e6,
                        rtt_secs: r2(rng.range_f64(5.0, 20.0)) * 1e-3,
                    },
                );
            }
        }
        for k in 0..hubs {
            for c in 0..cloud_hosts {
                b = b.link(
                    hub_base + k,
                    cloud_base + c,
                    LinkParams {
                        bandwidth_bps: rng.range(200, 1001) as f64 * 1e6,
                        rtt_secs: r2(rng.range_f64(2.0, 10.0)) * 1e-3,
                    },
                );
            }
        }
        for c1 in 0..cloud_hosts {
            for c2 in c1 + 1..cloud_hosts {
                b = b.link(
                    cloud_base + c1,
                    cloud_base + c2,
                    LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-3 },
                );
            }
        }
        b.camera(0).sink(0).build()
    }

    /// Random cluster: `n / 4` hosts (each guaranteed occupied),
    /// uniformly random device kinds (40% TEE / 35% CPU / 25% GPU,
    /// resource 0 forced TEE so the graph has an entry), log-uniform
    /// speeds in [0.25, 4), and `2 · hosts` random links.
    fn gen_random(n: usize, seed: u64) -> Result<Topology> {
        if n < 1 {
            bail!("random topologies need at least 1 resource");
        }
        let hosts = (n / 4).max(1);
        let mut rng = Rng::new(seed);
        let mut b = Topology::builder(format!("rand{n}-s{seed}"));
        for i in 0..n {
            let host = if i < hosts { i } else { rng.range(0, hosts) };
            let kind = if i == 0 {
                DeviceKind::Tee
            } else {
                let roll = rng.f64();
                if roll < 0.4 {
                    DeviceKind::Tee
                } else if roll < 0.75 {
                    DeviceKind::UntrustedCpu
                } else {
                    DeviceKind::Gpu
                }
            };
            let speed = r2((rng.f64() * 4.0 - 2.0).exp2());
            b = b.resource_spec(res(format!("r{i}-{}", kind.name()), kind, host, speed));
        }
        if hosts >= 2 {
            let mut seen = std::collections::BTreeSet::new();
            let (mut added, target) = (0usize, 2 * hosts);
            for _ in 0..8 * hosts {
                let a = rng.range(0, hosts);
                let c = rng.range(0, hosts);
                if a == c || !seen.insert((a.min(c), a.max(c))) {
                    continue;
                }
                b = b.link(
                    a,
                    c,
                    LinkParams {
                        bandwidth_bps: rng.range(10, 1001) as f64 * 1e6,
                        rtt_secs: r2(rng.range_f64(1.0, 30.0)) * 1e-3,
                    },
                );
                added += 1;
                if added >= target {
                    break;
                }
            }
        }
        b.camera(0).sink(0).build()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_matches_seed_graph() {
        let t = Topology::paper_testbed();
        assert_eq!(t.len(), 5);
        assert_eq!(t.hosts(), 2);
        let names: Vec<&str> = t.resources().iter().map(|r| r.name.as_str()).collect();
        assert_eq!(names, ["TEE1", "TEE2", "E1", "E2", "GPU2"]);
        assert_eq!(t.tees().len(), 2);
        assert_eq!(t.gpus().len(), 1);
        assert_eq!(t.untrusted().len(), 3);
        assert_eq!(t.name_of(t.entry()), "TEE1");
        assert_eq!(t.host_of(t.require("GPU2").unwrap()), 1);
        assert_eq!(t.kind_of(t.require("E2").unwrap()), DeviceKind::UntrustedCpu);
    }

    #[test]
    fn link_lookup_is_symmetric_with_default_fallback() {
        let mut t = Topology::paper_testbed();
        assert_eq!(t.link(0, 1), LinkParams::default());
        t.set_link(1, 0, LinkParams { bandwidth_bps: 5e6, rtt_secs: 0.02 });
        assert_eq!(t.link(0, 1).bandwidth_bps, 5e6);
        assert_eq!(t.link(1, 0).bandwidth_bps, 5e6);
        // intra-host transfers are free, cross-host pay bandwidth + rtt
        assert_eq!(t.transfer_secs(1, 1, 1_000_000), 0.0);
        let tr = t.transfer_secs(0, 1, 5_000_000);
        assert!((tr - (5_000_000.0 * 8.0 / 5e6 + 0.02)).abs() < 1e-9, "{tr}");
    }

    #[test]
    fn transfer_matches_paper_30mbps() {
        let t = Topology::paper_testbed();
        // 3.75 MB at 30 Mbit/s = 1 s (+10 ms latency)
        let tr = t.transfer_secs(0, 1, 3_750_000);
        assert!((tr - 1.01).abs() < 1e-6, "{tr}");
    }

    #[test]
    fn crypto_secs_well_under_paper_bound() {
        // paper §VI-D: AES-128 enc+dec < 2.5 ms/frame for boundary tensors
        let t = Topology::paper_testbed();
        assert!(t.crypto_secs(400_000) < 2.5e-3);
    }

    #[test]
    fn calibrate_crypto_rate_rescales_sealed_hops() {
        let mut t = Topology::paper_testbed();
        let before = t.crypto_secs(1 << 20);
        t.calibrate_crypto_rate(2.0 * DEFAULT_CRYPTO_BYTES_PER_SEC);
        assert!((t.crypto_secs(1 << 20) - before / 2.0).abs() < 1e-12);
        // bogus measurements are ignored, not installed
        t.calibrate_crypto_rate(0.0);
        t.calibrate_crypto_rate(f64::NAN);
        assert_eq!(t.crypto_bytes_per_sec, 2.0 * DEFAULT_CRYPTO_BYTES_PER_SEC);
    }

    #[test]
    fn stage_secs_applies_speed_and_epc_override() {
        let prof = ModelProfile::millis_demo();
        let base = Topology::paper_testbed();
        let tee = base.require("TEE1").unwrap();
        let gpu = base.require("GPU2").unwrap();
        let t_tee = base.stage_secs(&prof, tee, 0..3);
        let t_gpu = base.stage_secs(&prof, gpu, 0..3);
        assert!((t_tee - 27e-3).abs() < 1e-12, "{t_tee}");
        assert!((t_gpu - 6e-3).abs() < 1e-12, "{t_gpu}");

        // a 2x-speed GPU halves the stage time
        let mut fast = ResourceSpec::new("GPUX", DeviceKind::Gpu, 1);
        fast.speed = 2.0;
        let t2 = Topology::builder("x")
            .resource("TEE1", DeviceKind::Tee, 0)
            .resource_spec(fast)
            .build()
            .unwrap();
        let gx = t2.require("GPUX").unwrap();
        assert!((t2.stage_secs(&prof, gx, 0..3) - 3e-3).abs() < 1e-12);

        // a tiny per-enclave EPC forces paging where the default does not
        let mut small = ResourceSpec::new("TEEX", DeviceKind::Tee, 0);
        small.epc = Some(EpcModel {
            epc_bytes: 1 << 20,
            runtime_bytes: 1 << 20,
            act_factor: 1.0,
            page_secs_per_byte: 1e-6,
        });
        let t3 = Topology::builder("y").resource_spec(small).build().unwrap();
        let tx = t3.require("TEEX").unwrap();
        let mut prof2 = prof.clone();
        prof2.param_bytes = vec![1 << 20; 6];
        assert!(t3.paging_secs(&prof2, tx, 0..3) > 0.0);
        assert_eq!(base.paging_secs(&prof2, gpu, 0..3), 0.0);
    }

    #[test]
    fn builder_rejects_malformed_graphs() {
        let e = Topology::builder("t").build().unwrap_err();
        assert!(e.to_string().contains("no resources"), "{e}");

        let e = Topology::builder("t")
            .resource("A", DeviceKind::Tee, 0)
            .resource("A", DeviceKind::Gpu, 0)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("duplicate resource name 'A'"), "{e}");

        let e = Topology::builder("t")
            .resource("GPU", DeviceKind::Gpu, 0)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("no enclave"), "{e}");

        let e = Topology::builder("t")
            .resource("T", DeviceKind::Tee, 0)
            .camera(3)
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("camera host"), "{e}");

        let e = Topology::builder("t")
            .resource("T", DeviceKind::Tee, 0)
            .resource("U", DeviceKind::Tee, 1)
            .link(0, 7, LinkParams::default())
            .build()
            .unwrap_err();
        assert!(e.to_string().contains("does not exist"), "{e}");
    }

    #[test]
    fn invoke_overhead_round_trips_and_validates() {
        let mut spec = ResourceSpec::new("TEE1", DeviceKind::Tee, 0);
        spec.invoke_overhead_secs = 2.5e-3;
        let topo = Topology::builder("oh").resource_spec(spec).build().unwrap();
        let text = topo.to_json().to_string_pretty();
        assert!(text.contains("invoke_overhead_secs"), "{text}");
        let back = Topology::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(topo, back);
        let id = back.require("TEE1").unwrap();
        assert!((back.invoke_overhead_of(id) - 2.5e-3).abs() < 1e-15);

        // default stays implicit: no key emitted, 0.0 on load
        let plain = Topology::paper_testbed();
        assert!(!plain.to_json().to_string_pretty().contains("invoke_overhead_secs"));
        assert_eq!(plain.invoke_overhead_of(plain.entry()), 0.0);

        // negative overhead is rejected
        let mut bad = ResourceSpec::new("T", DeviceKind::Tee, 0);
        bad.invoke_overhead_secs = -1.0;
        let e = Topology::builder("bad").resource_spec(bad).build().unwrap_err();
        assert!(e.to_string().contains("negative invoke overhead"), "{e}");
    }

    #[test]
    fn json_round_trips_exactly() {
        let mut custom = Topology::builder("rt")
            .resource("TEE1", DeviceKind::Tee, 0)
            .resource("TEE2", DeviceKind::Tee, 1)
            .resource("GPU", DeviceKind::Gpu, 1)
            .link(0, 1, LinkParams { bandwidth_bps: 12.5e6, rtt_secs: 3e-3 })
            .crypto_rate(123e6)
            .camera(0)
            .sink(1)
            .build()
            .unwrap();
        custom.default_link = LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 };
        for topo in [Topology::paper_testbed(), custom] {
            let text = topo.to_json().to_string_pretty();
            let back = Topology::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(topo, back, "round trip changed the topology:\n{text}");
        }
    }

    #[test]
    fn json_resolves_names_and_rejects_unknowns() {
        // camera/link endpoints as resource names
        let j = Json::parse(
            r#"{
              "name": "named",
              "resources": [
                {"name": "T1", "kind": "tee", "host": 0},
                {"name": "G", "kind": "gpu", "host": 1}
              ],
              "camera": "T1",
              "links": [{"a": "T1", "b": "G", "bandwidth_mbps": 100, "rtt_ms": 1}]
            }"#,
        )
        .unwrap();
        let t = Topology::from_json(&j).unwrap();
        assert_eq!(t.camera_host, 0);
        assert!((t.link(0, 1).bandwidth_bps - 100e6).abs() < 1e-6);

        // link to a resource that does not exist
        let j = Json::parse(
            r#"{
              "resources": [{"name": "T1", "kind": "tee", "host": 0}],
              "links": [{"a": "T1", "b": "NOPE"}]
            }"#,
        )
        .unwrap();
        let e = Topology::from_json(&j).unwrap_err();
        assert!(format!("{e:#}").contains("unknown resource 'NOPE'"), "{e:#}");

        // missing host
        let j = Json::parse(r#"{"resources": [{"name": "T1", "kind": "tee"}]}"#).unwrap();
        let e = Topology::from_json(&j).unwrap_err();
        assert!(format!("{e:#}").contains("missing json key 'host'"), "{e:#}");

        // duplicate resource name
        let j = Json::parse(
            r#"{"resources": [
                 {"name": "T1", "kind": "tee", "host": 0},
                 {"name": "T1", "kind": "tee", "host": 1}
               ]}"#,
        )
        .unwrap();
        let e = Topology::from_json(&j).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate resource name"), "{e:#}");

        // unknown kind
        let j =
            Json::parse(r#"{"resources": [{"name": "Q", "kind": "quantum", "host": 0}]}"#).unwrap();
        assert!(Topology::from_json(&j).is_err());
    }

    #[test]
    fn json_rejects_typoed_keys_and_inherits_file_default_link() {
        // a typo'd link field must not silently fall back to defaults
        let j = Json::parse(
            r#"{
              "resources": [
                {"name": "T1", "kind": "tee", "host": 0},
                {"name": "T2", "kind": "tee", "host": 1}
              ],
              "links": [{"a": 0, "b": 1, "bandwith_mbps": 100}]
            }"#,
        )
        .unwrap();
        let e = Topology::from_json(&j).unwrap_err();
        assert!(format!("{e:#}").contains("unknown link key 'bandwith_mbps'"), "{e:#}");

        // unknown top-level / resource keys are rejected too
        let j = Json::parse(r#"{"resources": [], "topologee": 1}"#).unwrap();
        assert!(format!("{:#}", Topology::from_json(&j).unwrap_err()).contains("topologee"));
        let j = Json::parse(
            r#"{"resources": [{"name": "T", "kind": "tee", "host": 0, "hosty": 2}]}"#,
        )
        .unwrap();
        assert!(format!("{:#}", Topology::from_json(&j).unwrap_err()).contains("hosty"));

        // fields a link leaves unspecified inherit the file's default_link
        let j = Json::parse(
            r#"{
              "resources": [
                {"name": "T1", "kind": "tee", "host": 0},
                {"name": "T2", "kind": "tee", "host": 1}
              ],
              "default_link": {"bandwidth_mbps": 50, "rtt_ms": 5},
              "links": [{"a": 0, "b": 1, "bandwidth_mbps": 100}]
            }"#,
        )
        .unwrap();
        let t = Topology::from_json(&j).unwrap();
        assert!((t.link(0, 1).bandwidth_bps - 100e6).abs() < 1e-6);
        assert!((t.link(0, 1).rtt_secs - 5e-3).abs() < 1e-12, "rtt inherits default_link");
    }
}
