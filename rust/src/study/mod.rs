//! User-study simulation (paper §VI-B, Figs. 9–11).
//!
//! The paper ran a 10-subject survey: (part 1) identify the object in an
//! intermediate-layer output; (part 2) rank five layer outputs by
//! similarity to the original image. We cannot run human subjects; we
//! reproduce the *mechanism* the study measures — information destruction
//! by resolution loss — with a recognition proxy (template correlation
//! over downsampled synthetic object images + a psychometric noise model)
//! and simulated rankers (DESIGN.md §2). The knee the paper found at
//! 20×20 px is an emergent property of the proxy, not an input: templates
//! become indistinguishable once downsampling erases their discriminative
//! detail.

pub mod ranking;
pub mod recognizer;

pub use ranking::{simulate_ranking, RankingReport};
pub use recognizer::{accuracy_by_resolution, ObjectClass, Recognizer};
