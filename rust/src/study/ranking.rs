//! Ranking consensus simulation for the survey's part 2 (Fig. 11).
//!
//! Each "question" shows an original image and 5 degraded versions (distinct
//! resolutions); subjects rank them by similarity to the original. The paper
//! found: high-resolution images get inconsistent ranks (plenty of visible
//! structure ⇒ opinions differ) while everybody agrees on the lowest ranks —
//! consensus grows as resolution falls below ~20×20.
//!
//! Subject model (Weber–Fechner style): perceived similarity grows with the
//! log of the perceivable resolution (each halving of resolution is one
//! "just noticeable" step of degradation), while *disagreement* between
//! subjects scales with how much interpretable structure remains — a
//! high-resolution image offers many aspects to weigh (texture? shape?
//! colour?), a 14×14 mush offers none, so everyone drops it to the bottom.
//! The Pearson similarity of the actual degraded images is computed
//! alongside and asserted to be monotone in resolution, tying the
//! psychometric model to the real image content.

use super::recognizer::{render_object, ObjectClass, BASE_RES};
use crate::privacy::metrics::pearson;
use crate::util::rng::Rng;

/// Result: for each rank position 1..=5, the fraction of subject answers
/// agreeing with the resolution-based ranking.
#[derive(Debug, Clone)]
pub struct RankingReport {
    /// Agreement fraction per rank position 1..=5.
    pub agreement_by_rank: [f64; 5],
    /// Questions asked.
    pub questions: usize,
    /// Simulated subjects.
    pub subjects: usize,
}

/// Simulate the survey: `subjects` rankers × one question per model-like
/// resolution ladder. `resolutions` are the 5 distinct grid-cell sizes.
pub fn simulate_ranking(
    resolutions: [usize; 5],
    subjects: usize,
    questions: usize,
    seed: u64,
) -> RankingReport {
    let mut rng = Rng::new(seed);
    let mut agree_counts = [0usize; 5];
    let mut totals = [0usize; 5];

    for q in 0..questions {
        let class = *rng.choose(&ObjectClass::ALL);
        let orig = render_object(class, &mut rng);

        // candidate images + their true (image-content) similarity — used
        // as a sanity anchor for the psychometric model
        let candidates: Vec<(usize, f64)> = resolutions
            .iter()
            .map(|&r| {
                let deg = orig.downsample(r, r).resize(BASE_RES, BASE_RES);
                (r, pearson(&orig, &deg))
            })
            .collect();
        // resolution ordering and content-similarity ordering must agree
        for w in candidates.windows(2) {
            debug_assert!(
                w[0].0 < w[1].0 || w[0].1 >= w[1].1 - 0.05,
                "content similarity wildly inconsistent with resolution"
            );
        }

        // ground-truth ranking = by resolution, descending (rank 1 = highest)
        let mut truth: Vec<usize> = (0..5).collect();
        truth.sort_by(|&a, &b| candidates[b].0.cmp(&candidates[a].0));

        let (rmin, rmax) = (
            *resolutions.iter().min().unwrap() as f64,
            *resolutions.iter().max().unwrap() as f64,
        );
        for s in 0..subjects {
            let mut subj_rng = rng.fork((q * 1000 + s) as u64);
            let scored: Vec<(usize, f64)> = candidates
                .iter()
                .enumerate()
                .map(|(i, &(r, _))| {
                    // perceived similarity ∝ log perceivable resolution;
                    // inter-subject disagreement ∝ remaining structure
                    let detail = (r as f64).log2();
                    let frac = ((r as f64).log2() - rmin.log2()) / (rmax.log2() - rmin.log2());
                    let sigma = 0.08 + 0.85 * frac;
                    (i, detail + sigma * subj_rng.normal())
                })
                .collect();
            let mut perceived: Vec<usize> = (0..5).collect();
            perceived.sort_by(|&a, &b| {
                scored[b].1.partial_cmp(&scored[a].1).unwrap()
            });
            for rank in 0..5 {
                totals[rank] += 1;
                if perceived[rank] == truth[rank] {
                    agree_counts[rank] += 1;
                }
            }
        }
    }

    let mut agreement = [0f64; 5];
    for i in 0..5 {
        agreement[i] = agree_counts[i] as f64 / totals[i] as f64;
    }
    RankingReport { agreement_by_rank: agreement, questions, subjects }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's resolution ladder (Fig. 9 example: 114 → 14 px).
    const LADDER: [usize; 5] = [114, 57, 29, 20, 14];

    #[test]
    fn consensus_highest_at_the_bottom_ranks() {
        let r = simulate_ranking(LADDER, 10, 10, 42);
        let a = r.agreement_by_rank;
        // paper: everyone agrees on ranks 4-5; rank 1 is contested
        assert!(a[4] > a[0], "rank5 {} !> rank1 {}", a[4], a[0]);
        assert!(a[3] + a[4] > a[0] + a[1], "bottom ranks should beat top ranks");
    }

    #[test]
    fn low_ranks_reach_strong_consensus() {
        let r = simulate_ranking(LADDER, 10, 20, 7);
        assert!(r.agreement_by_rank[4] > 0.6, "{:?}", r.agreement_by_rank);
    }

    #[test]
    fn agreement_fractions_are_probabilities() {
        let r = simulate_ranking(LADDER, 5, 5, 3);
        for &a in &r.agreement_by_rank {
            assert!((0.0..=1.0).contains(&a));
        }
    }

    #[test]
    fn deterministic_in_seed() {
        let a = simulate_ranking(LADDER, 4, 4, 9).agreement_by_rank;
        let b = simulate_ranking(LADDER, 4, 4, 9).agreement_by_rank;
        assert_eq!(a, b);
    }
}
