//! Recognition proxy for the survey's part 1 (Fig. 10): accuracy of
//! object identification vs the resolution of the image shown.
//!
//! Ten object classes (the paper's: cat, dog, car, truck, bus, aeroplane,
//! boat, horse, elephant, person) are modelled as parametric silhouettes
//! with class-specific shape + texture detail. A "subject" sees the image
//! after it has been downsampled to the intermediate layer's grid-cell
//! resolution (then freely upscaled — the survey let users resize), and
//! answers with the class whose template correlates best, degraded by
//! psychometric noise that grows as discriminative evidence shrinks.

use crate::privacy::metrics::{pearson, Image};
use crate::util::rng::Rng;

/// Rendering resolution of the undegraded object templates (px).
pub const BASE_RES: usize = 128;

/// The paper's ten Imagenet classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[allow(missing_docs)] // the variants are the class names themselves
pub enum ObjectClass {
    Cat,
    Dog,
    Car,
    Truck,
    Bus,
    Aeroplane,
    Boat,
    Horse,
    Elephant,
    Person,
}

impl ObjectClass {
    /// All ten classes, in the paper's order.
    pub const ALL: [ObjectClass; 10] = [
        ObjectClass::Cat,
        ObjectClass::Dog,
        ObjectClass::Car,
        ObjectClass::Truck,
        ObjectClass::Bus,
        ObjectClass::Aeroplane,
        ObjectClass::Boat,
        ObjectClass::Horse,
        ObjectClass::Elephant,
        ObjectClass::Person,
    ];

    /// Lowercase class name.
    pub fn name(self) -> &'static str {
        match self {
            ObjectClass::Cat => "cat",
            ObjectClass::Dog => "dog",
            ObjectClass::Car => "car",
            ObjectClass::Truck => "truck",
            ObjectClass::Bus => "bus",
            ObjectClass::Aeroplane => "aeroplane",
            ObjectClass::Boat => "boat",
            ObjectClass::Horse => "horse",
            ObjectClass::Elephant => "elephant",
            ObjectClass::Person => "person",
        }
    }

    fn index(self) -> usize {
        Self::ALL.iter().position(|&c| c == self).unwrap()
    }
}

/// Render a class instance at BASE_RES with instance jitter (position,
/// scale, texture) — "100 images from Imagenet" stand-ins.
pub fn render_object(class: ObjectClass, rng: &mut Rng) -> Image {
    let mut im = Image::new(BASE_RES, BASE_RES);
    let cx = BASE_RES as f32 * (0.46 + 0.08 * rng.f32());
    let cy = BASE_RES as f32 * (0.46 + 0.08 * rng.f32());
    let scale = 0.9 + 0.2 * rng.f32();
    let idx = class.index();

    // class-specific silhouette + high-frequency detail: the detail is
    // what downsampling destroys first, mirroring real photos
    for y in 0..BASE_RES {
        for x in 0..BASE_RES {
            let dx = (x as f32 - cx) / (scale * BASE_RES as f32);
            let dy = (y as f32 - cy) / (scale * BASE_RES as f32);
            let mut v = 0.08; // background
            let body = match class {
                // animals: elliptical body + legs/head bumps
                ObjectClass::Cat | ObjectClass::Dog | ObjectClass::Horse | ObjectClass::Elephant => {
                    let e = (dx / 0.30).powi(2) + (dy / (0.16 + 0.02 * idx as f32)).powi(2);
                    let legs = (dy > 0.08 && (dx.abs() * 9.0).fract() < 0.35) as i32 as f32;
                    (e < 1.0) as i32 as f32 * (0.55 + 0.1 * legs)
                }
                // vehicles: rectangle + wheels
                ObjectClass::Car | ObjectClass::Truck | ObjectClass::Bus => {
                    let h = 0.10 + 0.035 * (idx as f32 - 2.0);
                    let rect = (dx.abs() < 0.32 && dy.abs() < h) as i32 as f32;
                    let wheel = (((dx.abs() - 0.2).powi(2) + (dy - h).powi(2)) < 0.004) as i32 as f32;
                    rect * 0.6 + wheel * 0.4
                }
                ObjectClass::Aeroplane => {
                    let fuselage = (dx.abs() < 0.38 && dy.abs() < 0.05) as i32 as f32;
                    let wings = (dy.abs() < 0.26 && dx.abs() < 0.07) as i32 as f32;
                    (fuselage + wings).min(1.0) * 0.6
                }
                ObjectClass::Boat => {
                    let hull = (dy > 0.0 && dy < 0.14 && dx.abs() < 0.3 - dy) as i32 as f32;
                    let mast = (dx.abs() < 0.02 && dy > -0.3 && dy <= 0.0) as i32 as f32;
                    (hull + mast).min(1.0) * 0.6
                }
                ObjectClass::Person => {
                    let head = ((dx / 0.07).powi(2) + ((dy + 0.2) / 0.07).powi(2) < 1.0) as i32 as f32;
                    let torso = (dx.abs() < 0.09 && dy > -0.12 && dy < 0.15) as i32 as f32;
                    let legs = (dy >= 0.15 && dy < 0.35 && (dx.abs() - 0.045).abs() < 0.035) as i32
                        as f32;
                    (head + torso + legs).min(1.0) * 0.6
                }
            };
            if body > 0.0 {
                // class-keyed texture (stripes/spots at class frequency):
                // the discriminative high-frequency evidence — deliberately
                // strong, so resolution loss is what destroys identity
                let f = 7.0 + idx as f32 * 3.3;
                let tex = 0.55
                    * ((x as f32 * f / BASE_RES as f32 * std::f32::consts::TAU).sin()
                        * (y as f32 * (f * 0.7) / BASE_RES as f32 * std::f32::consts::TAU).cos());
                v = body + tex + 0.10 * rng.f32();
            } else {
                v += 0.04 * rng.f32();
            }
            im.set(x, y, v);
        }
    }
    im
}

/// Template-correlation recognizer with a psychometric noise model.
pub struct Recognizer {
    templates: Vec<Image>,
    /// Subject inconsistency: noise added to each class score.
    pub decision_noise: f64,
}

impl Recognizer {
    /// Templates are canonical renders (no jitter) of each class.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let templates = ObjectClass::ALL
            .iter()
            .map(|&c| {
                // canonical: average several renders to suppress jitter
                let mut acc = Image::new(BASE_RES, BASE_RES);
                let k = 4;
                for _ in 0..k {
                    let im = render_object(c, &mut rng);
                    for (a, b) in acc.px.iter_mut().zip(&im.px) {
                        *a += b / k as f32;
                    }
                }
                acc
            })
            .collect();
        Recognizer { templates, decision_noise: 0.05 }
    }

    /// Identify the class of `shown` (an image already degraded to some
    /// resolution and upscaled back). Returns the argmax class.
    pub fn identify(&self, shown: &Image, rng: &mut Rng) -> ObjectClass {
        let mut best = (f64::MIN, ObjectClass::Cat);
        for (i, t) in self.templates.iter().enumerate() {
            let score = pearson(shown, t) + self.decision_noise * rng.normal();
            if score > best.0 {
                best = (score, ObjectClass::ALL[i]);
            }
        }
        best.1
    }
}

/// Fig. 10's experiment: accuracy of identification vs resolution band.
/// Returns (resolution, accuracy) for each requested resolution.
pub fn accuracy_by_resolution(
    resolutions: &[usize],
    images_per_class: usize,
    seed: u64,
) -> Vec<(usize, f64)> {
    let rec = Recognizer::new(seed);
    let mut rng = Rng::new(seed ^ 0x5757);
    resolutions
        .iter()
        .map(|&res| {
            let mut correct = 0usize;
            let mut total = 0usize;
            for &class in &ObjectClass::ALL {
                for _ in 0..images_per_class {
                    let orig = render_object(class, &mut rng);
                    // degrade to the intermediate layer's grid-cell
                    // resolution, then upscale (subjects may resize)
                    let shown = orig.downsample(res, res).resize(BASE_RES, BASE_RES);
                    if rec.identify(&shown, &mut rng) == class {
                        correct += 1;
                    }
                    total += 1;
                }
            }
            (res, correct as f64 / total as f64)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_are_deterministic_per_seed() {
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        assert_eq!(render_object(ObjectClass::Car, &mut a).px,
                   render_object(ObjectClass::Car, &mut b).px);
    }

    #[test]
    fn full_resolution_recognition_is_accurate() {
        let acc = accuracy_by_resolution(&[BASE_RES], 6, 42);
        assert!(acc[0].1 >= 0.9, "full-res accuracy {} too low", acc[0].1);
    }

    #[test]
    fn tiny_resolution_recognition_near_chance() {
        let acc = accuracy_by_resolution(&[4], 6, 42);
        assert!(acc[0].1 <= 0.45, "4px accuracy {} suspiciously high", acc[0].1);
    }

    #[test]
    fn accuracy_degrades_with_resolution() {
        // the psychometric curve must be (weakly) monotone across bands
        let acc = accuracy_by_resolution(&[128, 32, 12, 4], 8, 7);
        assert!(acc[0].1 > acc[2].1, "128px {} !> 12px {}", acc[0].1, acc[2].1);
        assert!(acc[1].1 > acc[3].1, "32px {} !> 4px {}", acc[1].1, acc[3].1);
    }

    #[test]
    fn knee_is_near_20px() {
        // paper: ~100% above 110px; drastic drop below 20px
        let acc = accuracy_by_resolution(&[110, 20, 8], 8, 11);
        let hi = acc[0].1;
        let knee = acc[1].1;
        let lo = acc[2].1;
        assert!(hi > 0.85, "high-res {hi}");
        assert!(lo < hi - 0.3, "low-res {lo} vs {hi}");
        assert!(knee < hi + 1e-9 && knee > lo - 1e-9);
    }
}
