//! Image similarity metrics: MSE, Pearson correlation, SSIM — the
//! candidate similarity functions of the paper's §IV, all implemented from
//! scratch over a simple grayscale image type. Also bilinear resampling,
//! since the paper asks survey subjects to "resize the images as much as
//! they can" — comparisons are done at a common resolution.

/// Grayscale f32 image (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    /// Width in pixels.
    pub w: usize,
    /// Height in pixels.
    pub h: usize,
    /// Row-major pixel values.
    pub px: Vec<f32>,
}

impl Image {
    /// A black (all-zero) image of the given size.
    pub fn new(w: usize, h: usize) -> Self {
        Image { w, h, px: vec![0.0; w * h] }
    }

    /// Pixel at (x, y).
    pub fn at(&self, x: usize, y: usize) -> f32 {
        self.px[y * self.w + x]
    }

    /// Set pixel at (x, y).
    pub fn set(&mut self, x: usize, y: usize, v: f32) {
        self.px[y * self.w + x] = v;
    }

    /// Bilinear resample to (nw, nh).
    pub fn resize(&self, nw: usize, nh: usize) -> Image {
        assert!(nw > 0 && nh > 0 && self.w > 0 && self.h > 0);
        let mut out = Image::new(nw, nh);
        for y in 0..nh {
            for x in 0..nw {
                // map output pixel center into source coordinates
                let sx = (x as f32 + 0.5) * self.w as f32 / nw as f32 - 0.5;
                let sy = (y as f32 + 0.5) * self.h as f32 / nh as f32 - 0.5;
                let x0 = sx.floor().clamp(0.0, (self.w - 1) as f32) as usize;
                let y0 = sy.floor().clamp(0.0, (self.h - 1) as f32) as usize;
                let x1 = (x0 + 1).min(self.w - 1);
                let y1 = (y0 + 1).min(self.h - 1);
                let fx = (sx - x0 as f32).clamp(0.0, 1.0);
                let fy = (sy - y0 as f32).clamp(0.0, 1.0);
                let v = self.at(x0, y0) * (1.0 - fx) * (1.0 - fy)
                    + self.at(x1, y0) * fx * (1.0 - fy)
                    + self.at(x0, y1) * (1.0 - fx) * fy
                    + self.at(x1, y1) * fx * fy;
                out.set(x, y, v);
            }
        }
        out
    }

    /// Downsample by area-average to (nw, nh) — models the information
    /// destruction of pooling/strided convolution.
    pub fn downsample(&self, nw: usize, nh: usize) -> Image {
        let mut out = Image::new(nw, nh);
        for y in 0..nh {
            for x in 0..nw {
                let x0 = x * self.w / nw;
                let x1 = ((x + 1) * self.w / nw).max(x0 + 1).min(self.w);
                let y0 = y * self.h / nh;
                let y1 = ((y + 1) * self.h / nh).max(y0 + 1).min(self.h);
                let mut s = 0.0;
                for yy in y0..y1 {
                    for xx in x0..x1 {
                        s += self.at(xx, yy);
                    }
                }
                out.set(x, y, s / ((x1 - x0) * (y1 - y0)) as f32);
            }
        }
        out
    }

    /// Mean pixel value.
    pub fn mean(&self) -> f64 {
        self.px.iter().map(|&v| v as f64).sum::<f64>() / self.px.len() as f64
    }

    /// Pixel variance.
    pub fn var(&self) -> f64 {
        let m = self.mean();
        self.px.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>() / self.px.len() as f64
    }
}

fn common_size<'a>(a: &'a Image, b: &'a Image) -> (Image, Image) {
    if a.w == b.w && a.h == b.h {
        (a.clone(), b.clone())
    } else {
        // compare at the larger resolution (subjects may upscale freely)
        let w = a.w.max(b.w);
        let h = a.h.max(b.h);
        (a.resize(w, h), b.resize(w, h))
    }
}

/// Mean squared error (lower = more similar).
pub fn mse(a: &Image, b: &Image) -> f64 {
    let (a, b) = common_size(a, b);
    a.px.iter()
        .zip(&b.px)
        .map(|(&x, &y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.px.len() as f64
}

/// Pearson correlation coefficient in [-1, 1] (higher = more similar).
pub fn pearson(a: &Image, b: &Image) -> f64 {
    let (a, b) = common_size(a, b);
    let (ma, mb) = (a.mean(), b.mean());
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (&x, &y) in a.px.iter().zip(&b.px) {
        let (vx, vy) = (x as f64 - ma, y as f64 - mb);
        num += vx * vy;
        da += vx * vx;
        db += vy * vy;
    }
    if da == 0.0 || db == 0.0 {
        return 0.0;
    }
    num / (da.sqrt() * db.sqrt())
}

/// Structural similarity (global SSIM over the whole image, L = dynamic
/// range of the pair). Higher = more similar, 1.0 = identical.
pub fn ssim(a: &Image, b: &Image) -> f64 {
    let (a, b) = common_size(a, b);
    let l = a
        .px
        .iter()
        .chain(&b.px)
        .fold((f32::MAX, f32::MIN), |(lo, hi), &v| (lo.min(v), hi.max(v)));
    let range = (l.1 - l.0).max(1e-6) as f64;
    let (c1, c2) = ((0.01 * range).powi(2), (0.03 * range).powi(2));
    let (ma, mb) = (a.mean(), b.mean());
    let (va, vb) = (a.var(), b.var());
    let cov = a
        .px
        .iter()
        .zip(&b.px)
        .map(|(&x, &y)| (x as f64 - ma) * (y as f64 - mb))
        .sum::<f64>()
        / a.px.len() as f64;
    ((2.0 * ma * mb + c1) * (2.0 * cov + c2))
        / ((ma * ma + mb * mb + c1) * (va + vb + c2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn noise_img(seed: u64, w: usize, h: usize) -> Image {
        let mut r = Rng::new(seed);
        let mut im = Image::new(w, h);
        for v in im.px.iter_mut() {
            *v = r.f32();
        }
        im
    }

    #[test]
    fn identical_images_are_maximally_similar() {
        let a = noise_img(1, 16, 16);
        assert_eq!(mse(&a, &a), 0.0);
        assert!((pearson(&a, &a) - 1.0).abs() < 1e-9);
        assert!((ssim(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn independent_noise_is_dissimilar() {
        let a = noise_img(1, 32, 32);
        let b = noise_img(2, 32, 32);
        assert!(mse(&a, &b) > 0.05);
        assert!(pearson(&a, &b).abs() < 0.2);
        assert!(ssim(&a, &b) < 0.5);
    }

    #[test]
    fn downsampling_decreases_similarity_monotonically() {
        // the paper's core insight: more resolution loss => less similar.
        // Use an image with fine detail (noise texture + blob) so that
        // downsampling genuinely destroys information.
        let orig = {
            let mut r = Rng::new(99);
            let mut im = Image::new(64, 64);
            for y in 0..64 {
                for x in 0..64 {
                    let blob = if (x as i32 - 40).pow(2) + (y as i32 - 24).pow(2) < 90 {
                        0.8
                    } else {
                        0.0
                    };
                    im.set(x, y, 0.7 * r.f32() + blob);
                }
            }
            im
        };
        let mut last = f64::INFINITY;
        for res in [64usize, 32, 16, 8, 4] {
            let deg = orig.downsample(res, res).resize(64, 64);
            let p = pearson(&orig, &deg);
            assert!(p <= last + 1e-9, "pearson should not increase as res drops");
            last = p;
        }
        // severe downsampling must destroy most structure vs mild
        let hi = pearson(&orig, &orig.downsample(32, 32).resize(64, 64));
        let lo = pearson(&orig, &orig.downsample(4, 4).resize(64, 64));
        assert!(hi > lo + 0.1, "hi={hi} lo={lo}");
    }

    #[test]
    fn resize_preserves_constant_images() {
        let mut im = Image::new(10, 7);
        for v in im.px.iter_mut() {
            *v = 3.25;
        }
        let up = im.resize(23, 31);
        assert!(up.px.iter().all(|&v| (v - 3.25).abs() < 1e-6));
    }

    #[test]
    fn downsample_preserves_mean() {
        let im = noise_img(3, 32, 32);
        let d = im.downsample(8, 8);
        assert!((im.mean() - d.mean()).abs() < 0.02);
    }

    #[test]
    fn metrics_handle_size_mismatch() {
        let a = noise_img(4, 16, 16);
        let b = a.downsample(8, 8);
        // comparable without panicking; correlated since b derives from a
        // (box-filtered noise keeps only partial correlation after the
        // bilinear round trip)
        assert!(pearson(&a, &b) > 0.25);
        assert!(mse(&a, &b) < 0.2);
    }
}
