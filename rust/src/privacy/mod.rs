//! Privacy / similarity metrics between an original frame and an
//! intermediate layer output (paper §IV "NN Layer Profile" item 4 and §V).
//!
//! The paper's deployed metric is the **resolution** of a single grid-cell
//! image of the intermediate tensor: below δ = 20×20 px an output is
//! unidentifiable (validated by their user study, reproduced in `study/`).
//! The framework is explicitly "not restricted to using the resolution as
//! a metric", so the classical alternatives they evaluated — MSE, Pearson
//! correlation, SSIM — are implemented here too and exercised by the
//! privacy benches and the e2e example (which scores real tensors off the
//! PJRT runtime).

pub mod metrics;

pub use metrics::{mse, pearson, ssim, Image};

use crate::model::{BlockInfo, ModelInfo};

/// Similarity verdict for offloading the input of a block to an untrusted
/// device (constraint C2 of the problem definition).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Leakage {
    /// Grid-cell resolution of the tensor (px).
    pub resolution: u32,
    /// True if `resolution <= delta` (private / offloadable).
    pub private: bool,
}

/// Assess the leakage of the tensor feeding block `b` under threshold δ.
pub fn assess_block_input(b: &BlockInfo, delta: u32) -> Leakage {
    Leakage { resolution: b.in_res, private: b.in_res <= delta }
}

/// The paper's per-path similarity: max leakage over every layer placed on
/// an untrusted resource — here expressed as the *largest input resolution*
/// among offloaded blocks (resolution is anti-monotone in privacy).
pub fn path_max_resolution(model: &ModelInfo, offloaded: impl Iterator<Item = usize>) -> u32 {
    offloaded.map(|i| model.blocks[i].in_res).max().unwrap_or(0)
}

/// Convert a (1, H, W, C) f32 tensor into the paper's grid-cell view: the
/// single-channel image used for similarity scoring (channel-mean, the
/// visualization tool's default).
pub fn tensor_to_cell(data: &[f32], h: usize, w: usize, c: usize) -> Image {
    let mut px = vec![0f32; h * w];
    for y in 0..h {
        for x in 0..w {
            let mut s = 0f32;
            for ch in 0..c {
                s += data[(y * w + x) * c + ch];
            }
            px[y * w + x] = s / c as f32;
        }
    }
    Image { w, h, px }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_to_cell_channel_mean() {
        // 1x2x2x2 tensor; channels (1,3), (2,4), (0,0), (10,-10)
        let data = [1.0, 3.0, 2.0, 4.0, 0.0, 0.0, 10.0, -10.0];
        let img = tensor_to_cell(&data, 2, 2, 2);
        assert_eq!(img.px, vec![2.0, 3.0, 0.0, 0.0]);
    }
}
