//! Synthetic surveillance video (DESIGN.md §2 substitution for the paper's
//! three surveillance datasets): three scene kinds differing in object
//! type (car / person / boat), setting (outdoor street, indoor, harbour),
//! and motion pattern. Frames are 224×224×3 f32 in [0, 1] — the input
//! resolution all five models require — generated deterministically from a
//! seed, sampled at the paper's 1 fps.

use crate::runtime::Tensor;
use crate::util::rng::Rng;

/// Frame width in pixels (the models' input resolution).
pub const W: usize = 224;
/// Frame height in pixels.
pub const H: usize = 224;

/// The paper's three dataset flavours.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SceneKind {
    /// Outdoor street camera: moving cars, horizon line.
    Street,
    /// Indoor camera: person-sized blobs, static furniture.
    Indoor,
    /// Harbour camera: boats on a water band.
    Harbour,
}

impl SceneKind {
    /// The three scene kinds, in the paper's dataset order.
    pub const ALL: [SceneKind; 3] = [SceneKind::Street, SceneKind::Indoor, SceneKind::Harbour];

    /// Lowercase scene name.
    pub fn name(self) -> &'static str {
        match self {
            SceneKind::Street => "street",
            SceneKind::Indoor => "indoor",
            SceneKind::Harbour => "harbour",
        }
    }
}

/// Deterministic frame stream for one camera.
pub struct VideoSource {
    /// The scene this camera watches.
    pub kind: SceneKind,
    rng: Rng,
    t: u64,
    /// persistent object positions (x, y, velocity)
    objects: Vec<(f32, f32, f32)>,
    background: Vec<f32>,
}

impl VideoSource {
    /// A camera of the given scene kind, deterministic per seed.
    pub fn new(kind: SceneKind, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ (kind as u64) << 32);
        let n_objects = match kind {
            SceneKind::Street => 4,
            SceneKind::Indoor => 2,
            SceneKind::Harbour => 3,
        };
        let objects = (0..n_objects)
            .map(|_| {
                (
                    rng.f32() * W as f32,
                    (0.4 + 0.4 * rng.f32()) * H as f32,
                    (0.5 + rng.f32()) * if rng.bool(0.5) { 1.0 } else { -1.0 },
                )
            })
            .collect();
        // static background texture per camera
        let mut bg_rng = rng.fork(0xb6);
        let background = (0..W * H).map(|_| 0.25 + 0.1 * bg_rng.f32()).collect();
        VideoSource { kind, rng, t: 0, objects, background }
    }

    /// Next frame (1 second later at 1 fps).
    pub fn next_frame(&mut self) -> Tensor {
        let mut data = vec![0f32; H * W * 3];
        let (sky, ground) = match self.kind {
            SceneKind::Street => (0.55, 0.35),
            SceneKind::Indoor => (0.45, 0.40),
            SceneKind::Harbour => (0.60, 0.30),
        };
        for y in 0..H {
            for x in 0..W {
                let base = if y < H / 3 { sky } else { ground } + self.background[y * W + x] * 0.3;
                let idx = (y * W + x) * 3;
                data[idx] = base;
                data[idx + 1] = base * 0.95;
                data[idx + 2] = base * 1.05;
            }
        }
        // advance + draw objects (cars: wide, persons: tall, boats: hull)
        let (ow, oh) = match self.kind {
            SceneKind::Street => (26i32, 12i32),
            SceneKind::Indoor => (10, 26),
            SceneKind::Harbour => (30, 10),
        };
        for oi in 0..self.objects.len() {
            let (ref mut ox, oy, v) = self.objects[oi];
            *ox += v * 8.0;
            if *ox < -30.0 {
                *ox = W as f32 + 20.0;
            }
            if *ox > W as f32 + 30.0 {
                *ox = -20.0;
            }
            let shade = 0.1 + 0.6 * ((oi * 61) % 10) as f32 / 10.0;
            let (cx, cy) = (*ox as i32, oy as i32);
            for dy in -oh / 2..oh / 2 {
                for dx in -ow / 2..ow / 2 {
                    let (px, py) = (cx + dx, cy + dy);
                    if (0..W as i32).contains(&px) && (0..H as i32).contains(&py) {
                        let idx = (py as usize * W + px as usize) * 3;
                        data[idx] = shade;
                        data[idx + 1] = shade * 0.9;
                        data[idx + 2] = shade * 0.8;
                    }
                }
            }
        }
        // sensor noise
        for v in data.iter_mut() {
            *v = (*v + 0.02 * self.rng.f32()).clamp(0.0, 1.0);
        }
        self.t += 1;
        Tensor::new(vec![1, H, W, 3], data).expect("frame shape")
    }

    /// Chunk of n frames (the paper's chunk_k = <f_1 .. f_n>).
    pub fn chunk(&mut self, n: usize) -> Vec<Tensor> {
        (0..n).map(|_| self.next_frame()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_have_model_input_shape_and_range() {
        let mut src = VideoSource::new(SceneKind::Street, 1);
        let f = src.next_frame();
        assert_eq!(f.shape, vec![1, 224, 224, 3]);
        assert!(f.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = VideoSource::new(SceneKind::Indoor, 9).chunk(3);
        let b = VideoSource::new(SceneKind::Indoor, 9).chunk(3);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data, y.data);
        }
    }

    #[test]
    fn frames_change_over_time() {
        let mut src = VideoSource::new(SceneKind::Harbour, 2);
        let a = src.next_frame();
        let b = src.next_frame();
        assert_ne!(a.data, b.data, "objects must move between frames");
    }

    #[test]
    fn scenes_differ() {
        let a = VideoSource::new(SceneKind::Street, 5).next_frame();
        let b = VideoSource::new(SceneKind::Harbour, 5).next_frame();
        assert_ne!(a.data, b.data);
    }
}
