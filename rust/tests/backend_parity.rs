//! Backend parity: the pure-Rust reference backend must compute the
//! `ref.py` oracle semantics exactly. These tests pin it against fixed
//! golden tensors (small enough to verify by hand) end-to-end through the
//! `Backend` trait — synthetic manifest block + a real `params.bin` file
//! on disk — so the whole load→split→forward path is exercised without
//! the generated artifacts.

use serdab::model::{BlockInfo, ModelInfo};
use serdab::runtime::backend::reference::{ops, zoo, ReferenceBackend};
use serdab::runtime::{Backend, BlockRunner, Tensor};

fn blank_block(idx: usize, name: &str) -> BlockInfo {
    BlockInfo {
        idx,
        name: name.to_string(),
        hlo: String::new(),
        params: String::new(),
        golden: String::new(),
        params_sha256: String::new(),
        golden_sha256: String::new(),
        param_shapes: vec![],
        param_floats: 0,
        in_shape: vec![],
        out_shape: vec![],
        in_res: 1,
        out_res: 1,
        flops_full: 1,
        param_bytes_full: 1,
        out_bytes_full: 1,
        act_bytes_full: 1,
        peak_act_bytes_full: 1,
        n_ops: 1,
        kernel: None,
    }
}

/// Model skeleton whose block names match the zoo, with one real block.
fn model_with_block(model: &str, idx: usize, real: BlockInfo) -> ModelInfo {
    let defs = zoo::arch_blocks(model).expect("model in zoo");
    let blocks = defs
        .iter()
        .enumerate()
        .map(|(i, d)| if i == idx { real.clone() } else { blank_block(i, d.name) })
        .collect();
    ModelInfo {
        name: model.to_string(),
        tiny_width: 0.125,
        tiny_classes: 10,
        golden_input: String::new(),
        total_flops_full: 1,
        model_bytes_full: 1,
        blocks,
    }
}

fn write_params(dir: &std::path::Path, rel: &str, tensors: &[Tensor]) {
    let mut bytes = Vec::new();
    for t in tensors {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    std::fs::write(dir.join(rel), bytes).unwrap();
}

fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
    Tensor::new(shape.to_vec(), data).unwrap()
}

#[test]
fn head_block_through_backend_matches_golden() {
    // googlenet head = GAP → dense(no relu). Identity dense weights make
    // the golden output the channel means: [2.5, 25.0].
    let dir = std::env::temp_dir().join("serdab_parity_head");
    std::fs::create_dir_all(&dir).unwrap();
    let params = [t(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]), t(&[2], vec![0.0, 0.0])];
    write_params(&dir, "head.params.bin", &params);

    let mut head = blank_block(11, "head");
    head.params = "head.params.bin".into();
    head.param_shapes = vec![vec![2, 2], vec![2]];
    head.param_floats = 6;
    head.in_shape = vec![1, 2, 2, 2];
    head.out_shape = vec![1, 2];
    let model = model_with_block("googlenet", 11, head);

    let runner = ReferenceBackend.load_block(&dir, &model, 11).unwrap();
    let x = t(&[1, 2, 2, 2], vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0, 4.0, 40.0]);
    let y = runner.run(&x).unwrap();
    assert_eq!(y.shape, vec![1, 2]);
    assert_eq!(y.data, vec![2.5, 25.0]);
}

#[test]
fn fire_block_through_backend_matches_golden() {
    // squeezenet fire2 with hand-picked params: squeeze splits x into
    // [x, relu(-x)=0], expand-1x1 re-sums them (= x), expand-3x3 is the
    // constant 0.5 — golden output interleaves [x, 0.5] per pixel.
    let dir = std::env::temp_dir().join("serdab_parity_fire");
    std::fs::create_dir_all(&dir).unwrap();
    let params = [
        t(&[1, 1, 1, 2], vec![1.0, -1.0]),
        t(&[2], vec![0.0, 0.0]),
        t(&[1, 1, 2, 1], vec![1.0, 1.0]),
        t(&[1], vec![0.0]),
        t(&[3, 3, 2, 1], vec![0.0; 18]),
        t(&[1], vec![0.5]),
    ];
    write_params(&dir, "fire2.params.bin", &params);

    let mut fire = blank_block(1, "fire2");
    fire.params = "fire2.params.bin".into();
    fire.param_shapes = params.iter().map(|p| p.shape.clone()).collect();
    fire.param_floats = 26;
    fire.in_shape = vec![1, 2, 2, 1];
    fire.out_shape = vec![1, 2, 2, 2];
    let model = model_with_block("squeezenet", 1, fire);

    let runner = ReferenceBackend.load_block(&dir, &model, 1).unwrap();
    let x = t(&[1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]);
    let y = runner.run(&x).unwrap();
    assert_eq!(y.data, vec![1.0, 0.5, 2.0, 0.5, 3.0, 0.5, 4.0, 0.5]);
}

#[test]
fn backend_rejects_truncated_param_file() {
    let dir = std::env::temp_dir().join("serdab_parity_trunc");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("short.params.bin"), [0u8; 8]).unwrap();

    let mut head = blank_block(11, "head");
    head.params = "short.params.bin".into();
    head.param_shapes = vec![vec![2, 2], vec![2]];
    head.param_floats = 6;
    head.in_shape = vec![1, 2, 2, 2];
    head.out_shape = vec![1, 2];
    let model = model_with_block("googlenet", 11, head);
    let err = ReferenceBackend.load_block(&dir, &model, 11).unwrap_err();
    assert!(format!("{err:#}").contains("too short"), "{err:#}");
}

#[test]
fn conv_same_padding_matches_ref_py_golden() {
    // 3x3 all-ones SAME conv over the 3x3 ramp 1..9 — golden grid
    // computed by hand from ref.py's conv semantics (zero padding).
    let x = t(&[1, 3, 3, 1], (1..=9).map(|v| v as f32).collect());
    let w = t(&[3, 3, 1, 1], vec![1.0; 9]);
    let b = t(&[1], vec![0.0]);
    let y = ops::conv2d(&x, &w, &b, 1, &zoo::Pad::Same, false).unwrap();
    assert_eq!(
        y.data,
        vec![12.0, 21.0, 16.0, 27.0, 45.0, 33.0, 24.0, 39.0, 28.0]
    );
}

#[test]
fn strided_valid_pool_matches_ref_py_golden() {
    // 3x3 max pool, stride 2, VALID over a 5x5 ramp: centers at rows/cols
    // {1,3}; max of each window is its bottom-right corner.
    let x = t(&[1, 5, 5, 1], (1..=25).map(|v| v as f32).collect());
    let y = ops::pool2d(&x, 3, 2, true, &zoo::Pad::Valid).unwrap();
    assert_eq!(y.shape, vec![1, 2, 2, 1]);
    assert_eq!(y.data, vec![13.0, 15.0, 23.0, 25.0]);
}
