//! Acceptance for sharded multi-chain serving (DESIGN.md §18): the DES
//! says K parallel solved chains on the generated tree-64 fleet deliver
//! a real aggregate-throughput win over one chain; the live [`Dispatcher`]
//! admits, churns, and detaches streams across shards with zero frame
//! loss; and a repartition on one shard re-solves that shard alone.
//!
//! The live scenarios run on the synthetic builder (workers execute the
//! cost model's nominal service times), so no model artifacts are
//! needed. They share ONE #[test] so the sleep-based worker threads
//! never compete with a sibling test for cores.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use serdab::coordinator::{
    shard_topology, Dispatcher, DispatcherConfig, DispatcherEvent, ServerConfig, ServerEvent,
    StreamSpec, SyntheticBuilder,
};
use serdab::placement::cost::CostModel;
use serdab::placement::fleet::{self, SolverOpts};
use serdab::placement::strategies::Strategy;
use serdab::profiler::ModelProfile;
use serdab::sim::simulate_schedule;
use serdab::topology::{gen, Topology};

const CHUNK: u64 = 10_800;

/// Shard-server template for the live scenarios: fast monitor windows,
/// incremental re-solve on drift.
fn shard_server_config() -> ServerConfig {
    let base = ServerConfig::default();
    ServerConfig { window_secs: 0.1, incremental: true, ..base }
}

fn tree64() -> Topology {
    let spec = gen::GenSpec { kind: gen::GenKind::Tree, resources: 64, seed: 64 };
    gen::generate(&spec).unwrap()
}

/// Saturation throughput of the solved chain for one topology, per the
/// DES: frames arrive far faster than any chain can serve, so completed
/// frames per virtual second is the chain's service rate.
fn des_fps(profile: &ModelProfile, topo: &Topology) -> f64 {
    let cm = CostModel::new(profile, topo.clone());
    let fp = fleet::solve(Strategy::Proposed, &cm, CHUNK, &SolverOpts::default());
    let schedule: Vec<(f64, u32)> = (0..240).map(|f| (f as f64 * 1e-4, 0)).collect();
    let report = simulate_schedule(&cm, &fp.plan.placement, &schedule, 256);
    report.throughput()
}

/// Three shards of the tree-64 fleet must aggregate ≥ 2.5× the
/// throughput of the best single chain over the whole fleet — the
/// scale-out claim, settled in virtual time.
#[test]
fn three_shards_aggregate_des_throughput_beats_one_chain() {
    let profile = ModelProfile::millis_demo();
    let topo = tree64();
    let one_chain = des_fps(&profile, &topo);
    let shards = shard_topology(&topo, 3).unwrap();
    assert_eq!(shards.len(), 3);
    let aggregate: f64 = shards.iter().map(|s| des_fps(&profile, s)).sum();
    assert!(
        aggregate >= 2.5 * one_chain,
        "3 shards aggregate {aggregate:.1} fps < 2.5× one-chain {one_chain:.1} fps"
    );
}

/// Drain the merged event feed until `shard` completes a swap.
fn wait_for_shard_swap(events: &Receiver<DispatcherEvent>, shard: usize, timeout: Duration) {
    let deadline = Instant::now() + timeout;
    let mut seen = Vec::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(!left.is_zero(), "no swap on shard {shard} within {timeout:?}: {seen:?}");
        match events.recv_timeout(left) {
            Ok(ev) if ev.shard == shard => match ev.event {
                ServerEvent::SwapCompleted(_) => return,
                ServerEvent::SwapFailed { error } => panic!("swap failed: {error}"),
                other => seen.push((ev.shard, format!("{other:?}"))),
            },
            Ok(ev) => seen.push((ev.shard, format!("{:?}", ev.event))),
            Err(_) => panic!("event feed closed before shard {shard} swapped: {seen:?}"),
        }
    }
}

#[test]
fn dispatcher_serves_churns_and_repairs_per_shard() {
    churn_across_shards_loses_no_frames();
    repartition_touches_one_shard_only();
}

/// Streams attach through least-loaded routing with per-shard admission,
/// churn mid-run, and every fed frame drains — on every shard.
fn churn_across_shards_loses_no_frames() {
    let profile = ModelProfile::millis_demo();
    let topo = tree64();
    let server = shard_server_config();
    let cfg = DispatcherConfig { shards: 3, server, max_streams_per_shard: 4 };
    let builder_profile = profile.clone();
    let mut d = Dispatcher::launch(
        &profile,
        &topo,
        |st| Box::new(SyntheticBuilder::new(builder_profile.clone(), st.clone())),
        cfg,
    )
    .unwrap();
    assert_eq!(d.shards(), 3);

    // six cameras spread 2-2-2 by least-loaded admission
    let mut streams = Vec::new();
    for i in 0..6 {
        let s = d.attach(StreamSpec::synthetic(format!("cam-{i}"), 0.05, 64)).unwrap();
        streams.push(s);
    }
    for shard in 0..3 {
        let on_shard = streams.iter().filter(|s| s.shard == shard).count();
        assert_eq!(on_shard, 2, "least-loaded admission skewed: {shard}");
    }
    std::thread::sleep(Duration::from_millis(500));

    // churn: two cameras leave (their in-flight frames keep flowing to
    // completion — the zero-loss claim settles in the shutdown report),
    // two join
    let r0 = d.detach(streams[0].id).unwrap();
    let r3 = d.detach(streams[3].id).unwrap();
    assert!(r0.fed >= 2, "cam-0 barely fed: {r0:?}");
    assert!(r0.completed <= r0.fed, "cam-0 over-completed: {r0:?}");
    assert!(r3.completed <= r3.fed, "cam-3 over-completed: {r3:?}");
    for i in 6..8 {
        let s = d.attach(StreamSpec::synthetic(format!("cam-{i}"), 0.05, 64)).unwrap();
        streams.push(s);
    }
    std::thread::sleep(Duration::from_millis(400));

    let stats = d.cache_stats().expect("dispatcher installs a shared cache");
    assert!(stats.0 + stats.1 >= 3, "every shard launch consults the shared cache");

    let reports = d.shutdown().unwrap();
    assert_eq!(reports.len(), 3);
    for (i, rep) in reports.iter().enumerate() {
        assert_eq!(rep.frames_dropped, 0, "shard {i} dropped frames");
        assert_eq!(rep.sink_errors, 0, "shard {i} sink errors");
        for s in &rep.streams {
            assert_eq!(s.completed, s.fed, "shard {i} stream {} lost frames", s.label);
        }
    }
    let served: u64 = reports.iter().flat_map(|r| r.streams.iter().map(|s| s.fed)).sum();
    assert!(served > 0, "no frames served across the fleet");
}

/// An out-of-band repartition on shard 0 hot-swaps shard 0 — and only
/// shard 0; the siblings' swap histories stay empty.
fn repartition_touches_one_shard_only() {
    let profile = ModelProfile::millis_demo();
    let topo = tree64();
    let server = shard_server_config();
    let cfg = DispatcherConfig { shards: 3, server, max_streams_per_shard: 0 };
    let builder_profile = profile.clone();
    let mut d = Dispatcher::launch(
        &profile,
        &topo,
        |st| Box::new(SyntheticBuilder::new(builder_profile.clone(), st.clone())),
        cfg,
    )
    .unwrap();
    let events = d.events().expect("merged event feed is available once");

    // one camera per shard so every chain is live while shard 0 swaps
    for shard in 0..3 {
        d.attach_to(shard, StreamSpec::synthetic(format!("cam-{shard}"), 0.05, 64)).unwrap();
    }
    std::thread::sleep(Duration::from_millis(300));

    d.request_repartition(0, "test: forced drift on shard 0").unwrap();
    wait_for_shard_swap(&events, 0, Duration::from_secs(30));
    std::thread::sleep(Duration::from_millis(200));

    let swaps = d.swaps_by_shard();
    assert!(!swaps[0].is_empty(), "shard 0 must record its repartition");
    assert!(swaps[1].is_empty(), "shard 1 swapped although only shard 0 drifted");
    assert!(swaps[2].is_empty(), "shard 2 swapped although only shard 0 drifted");

    let reports = d.shutdown().unwrap();
    for (i, rep) in reports.iter().enumerate() {
        assert_eq!(rep.frames_dropped, 0, "shard {i} dropped frames across the swap");
    }
}
