//! Property-based invariants of the placement solver (the paper's §V
//! algorithm) using the in-repo mini-proptest framework.

use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::{DELTA_RESOLUTION, MODEL_NAMES};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::tree::full_tree;
use serdab::profiler::calibrated_profile;
use serdab::util::prop;

fn with_manifest(f: impl FnOnce(serdab::model::Manifest)) {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    f(load_manifest(dir).unwrap());
}

#[test]
fn prop_solver_output_always_valid_and_private() {
    with_manifest(|man| {
        let profiles: Vec<_> = MODEL_NAMES
            .iter()
            .map(|n| calibrated_profile(man.model(n).unwrap()))
            .collect();
        let gen = prop::pair(prop::usize_in(0, 4), prop::usize_in(1, 20_000));
        prop::forall("solver-valid", &gen, 40, |&(mi, n)| {
            let profile = &profiles[mi];
            let cm = CostModel::paper(profile);
            for strat in Strategy::ALL {
                let p = plan(strat, &cm, n as u64);
                p.placement
                    .validate(cm.topology(), profile.m)
                    .map_err(|e| format!("{strat:?}: {e}"))?;
                if !p.placement.satisfies_privacy(cm.topology(), &profile.in_res, DELTA_RESOLUTION)
                {
                    return Err(format!(
                        "{strat:?} leaked: {}",
                        p.placement.describe(cm.topology())
                    ));
                }
            }
            Ok(())
        });
    });
}

#[test]
fn prop_solver_is_argmin_over_its_tree() {
    // the chosen plan must cost no more than any privacy-feasible path in
    // the full paper tree
    with_manifest(|man| {
        let model = man.model("mobilenet").unwrap();
        let profile = calibrated_profile(model);
        let cm = CostModel::paper(&profile);
        let n = 10_800;
        let best = plan(Strategy::Proposed, &cm, n);
        let (paths, _) = full_tree(cm.topology(), profile.m);
        for p in paths {
            if !p.satisfies_privacy(cm.topology(), &profile.in_res, DELTA_RESOLUTION) {
                continue;
            }
            let c = cm.cost(&p).chunk_secs(n);
            assert!(
                best.cost.chunk_secs(n) <= c * (1.0 + 1e-9),
                "solver missed better path {} ({c}s)",
                p.describe(cm.topology())
            );
        }
    });
}

#[test]
fn prop_speedup_monotone_in_chunk_size_for_pipelined_strategies() {
    // pipeline parallelism pays off more as n grows: speedup(n=10800) >=
    // speedup(n=1) for every pipelined strategy
    with_manifest(|man| {
        for name in MODEL_NAMES {
            let profile = calibrated_profile(man.model(name).unwrap());
            let cm = CostModel::paper(&profile);
            for strat in [Strategy::TwoTees, Strategy::Proposed] {
                let base1 = plan(Strategy::OneTee, &cm, 1).cost.chunk_secs(1);
                let basen = plan(Strategy::OneTee, &cm, 10_800).cost.chunk_secs(10_800);
                let s1 = base1 / plan(strat, &cm, 1).cost.chunk_secs(1);
                let sn = basen / plan(strat, &cm, 10_800).cost.chunk_secs(10_800);
                assert!(
                    sn >= s1 - 1e-9,
                    "{name}/{strat:?}: speedup shrank with n ({s1:.2} -> {sn:.2})"
                );
            }
        }
    });
}

#[test]
fn prop_delta_sweep_moves_crossing_monotonically() {
    // lowering δ (stricter privacy) can only push the offload point deeper
    with_manifest(|man| {
        for name in MODEL_NAMES {
            let model = man.model(name).unwrap();
            let mut last = 0;
            for delta in [300u32, 60, 28, 20, 10, 4] {
                let c = model.privacy_crossing(delta);
                assert!(c >= last, "{name}: crossing not monotone in δ");
                last = c;
            }
        }
    });
}
