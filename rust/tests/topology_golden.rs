//! Golden parity: the data-driven topology solver must reproduce the
//! placements the hardcoded five-resource testbed produced — byte-
//! identical placement descriptions and strategy labels, bit-identical
//! costs. The "old" side of the comparison is the seed's chain family,
//! restated literally (TEE1→TEE2→GPU2, TEE1→TEE2→E2, TEE1→GPU2,
//! TEE1→E1), solved with exactly the seed's argmin loop; the "new" side
//! is `plan()` over `Topology::paper_testbed()`. This guards the API
//! redesign: if the generalized chain derivation ever drifts from the
//! paper's tree on the paper's graph, this fails.

use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::{DELTA_RESOLUTION, MODEL_NAMES};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, speedup_table, Strategy};
use serdab::placement::tree::enumerate_paths;
use serdab::placement::{Placement, ResourceId};
use serdab::profiler::{calibrated_profile, ModelProfile};

/// The seed's hardcoded chain family for one strategy, as resource names.
fn seed_chains(strategy: Strategy) -> Vec<Vec<&'static str>> {
    match strategy {
        Strategy::OneTee => vec![vec!["TEE1"]],
        Strategy::TeeGpu => vec![vec!["TEE1", "GPU2"]],
        Strategy::TwoTees => vec![vec!["TEE1", "TEE2"]],
        Strategy::NoPipelining | Strategy::Proposed => vec![
            vec!["TEE1", "TEE2", "GPU2"],
            vec!["TEE1", "TEE2", "E2"],
            vec!["TEE1", "GPU2"],
            vec!["TEE1", "E1"],
        ],
    }
}

/// The seed's solver loop, verbatim: enumerate each chain, filter by
/// privacy, strict-argmin the strategy objective.
fn seed_plan(strategy: Strategy, cm: &CostModel<'_>, n: u64) -> (Placement, f64) {
    let topo = cm.topology();
    let m = cm.profile.m;
    let mut best: Option<(f64, Placement)> = None;
    for chain in seed_chains(strategy) {
        let ids: Vec<ResourceId> = chain.iter().map(|r| topo.require(r).unwrap()).collect();
        for p in enumerate_paths(&ids, m) {
            if !p.satisfies_privacy(topo, &cm.profile.in_res, DELTA_RESOLUTION) {
                continue;
            }
            let cost = cm.cost(&p);
            let objective = match strategy {
                Strategy::NoPipelining => cost.single_secs,
                _ => cost.chunk_secs(n),
            };
            let better = match &best {
                None => true,
                Some((obj, _)) => objective < *obj,
            };
            if better {
                best = Some((objective, p));
            }
        }
    }
    let (obj, placement) = best.expect("seed solver found a path");
    (placement, obj)
}

fn assert_parity(cm: &CostModel<'_>, what: &str) {
    let topo = cm.topology();
    for n in [1u64, 10, 40, 1000, 10_800] {
        for strategy in Strategy::ALL {
            let new = plan(strategy, cm, n);
            let (old_placement, old_obj) = seed_plan(strategy, cm, n);
            assert_eq!(
                new.placement.describe(topo),
                old_placement.describe(topo),
                "{what}/{strategy:?}/n={n}: placement drifted from the seed graph"
            );
            let new_obj = match strategy {
                Strategy::NoPipelining => new.cost.single_secs,
                _ => new.cost.chunk_secs(n),
            };
            assert!(
                new_obj == old_obj,
                "{what}/{strategy:?}/n={n}: objective {new_obj} != seed {old_obj}"
            );
        }
    }
}

#[test]
fn paper_testbed_reproduces_hardcoded_solver_on_demo_profile() {
    let prof = ModelProfile::millis_demo();
    let cm = CostModel::paper(&prof);
    assert_parity(&cm, "millis-demo");
}

#[test]
fn paper_testbed_reproduces_hardcoded_solver_on_calibrated_models() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping calibrated parity: run `make artifacts`");
        return;
    }
    let man = load_manifest(dir).unwrap();
    for name in MODEL_NAMES {
        let profile = calibrated_profile(man.model(name).unwrap());
        let cm = CostModel::paper(&profile);
        assert_parity(&cm, name);
    }
}

#[test]
fn strategy_labels_are_the_figure_legend() {
    let labels: Vec<&str> = Strategy::ALL.iter().map(|s| s.name()).collect();
    assert_eq!(labels, ["1 TEE", "No pipelining", "1 TEE & 1 GPU", "2 TEEs", "Proposed"]);
}

#[test]
fn speedup_table_keeps_strategy_order_and_baseline() {
    let prof = ModelProfile::millis_demo();
    let cm = CostModel::paper(&prof);
    let table = speedup_table(&cm, 10_800);
    let order: Vec<Strategy> = table.iter().map(|(s, _, _)| *s).collect();
    assert_eq!(order, Strategy::ALL.to_vec());
    let one_tee = &table[0];
    assert!((one_tee.2 - 1.0).abs() < 1e-12, "baseline speedup must be 1.0");
    // every strategy's placement matches the seed solver too
    for (strategy, p, _) in &table {
        let (old_placement, _) = seed_plan(*strategy, &cm, 10_800);
        assert_eq!(
            p.placement.describe(cm.topology()),
            old_placement.describe(cm.topology())
        );
    }
}
