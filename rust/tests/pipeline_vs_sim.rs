//! Cross-validation of the *executed* pipeline runtime against the
//! discrete-event simulator: the same placement + cost inputs go through
//! both, and chunk-completion times must agree within tolerance. Passing
//! this turns the DES from a standalone model into a verified planning
//! oracle for the coordinator.
//!
//! The executed side uses `Pipeline::synthetic`: real worker threads, real
//! bounded channels and backpressure, real framed hand-offs — with each
//! operator sleeping exactly the service time the cost model charges, so
//! the comparison isolates the *pipeline semantics* (overlap, queueing,
//! blocking) rather than block-kernel speed, and needs no model artifacts.
//! Stage times are milliseconds-scale so scheduler noise stays far inside
//! the 15% acceptance band.

use serdab::coordinator::Monitor;
use serdab::coordinator::MonitorVerdict;
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::profiler::ModelProfile;
use serdab::runtime::pipeline::{FrameIn, Pipeline, PipelineConfig};
use serdab::sim::{simulate, SimConfig};

/// Run `strategy`'s solved placement through the DES (virtual time) and
/// the executed runtime (wall clock); assert agreement.
fn cross_validate(strategy: Strategy, frames: u64) {
    let prof = ModelProfile::millis_demo();
    let cm = CostModel::paper(&prof);
    let p = plan(strategy, &cm, frames);
    let cost = cm.cost(&p.placement);
    eprintln!(
        "{:?}: {} (period {:.1} ms)",
        strategy,
        p.placement.describe(cm.topology()),
        cost.period_secs * 1e3
    );

    let cfg = SimConfig { frames, arrival_secs: 0.0, queue_cap: 4 };
    let sim_rep = simulate(&cm, &p.placement, &cfg);

    let pipe = Pipeline::synthetic(cm.topology(), &p.placement, &cost, PipelineConfig::default());
    let feed = (0..frames).map(|_| FrameIn { stream: 0, payload: vec![0u8; 64] });
    let real = pipe.run(feed, |_| {}).expect("pipeline run");

    assert_eq!(real.frames, frames, "frames lost in the executed pipeline");

    // 1) chunk-completion time: the acceptance criterion (≤ 15%)
    let err = (real.completion_secs - sim_rep.completion_secs).abs() / sim_rep.completion_secs;
    assert!(
        err < 0.15,
        "{strategy:?}: executed {:.4}s vs DES {:.4}s ({:.1}% off)",
        real.completion_secs,
        sim_rep.completion_secs,
        err * 100.0
    );

    // 2) per-stage occupancy lines up server-by-server
    let sim_util = sim_rep.stage_utilization();
    let real_occ = real.stage_occupancy();
    assert_eq!(sim_util.len(), real_occ.len(), "stage arity mismatch");
    for (i, (s, r)) in sim_util.iter().zip(&real_occ).enumerate() {
        assert!(
            (s - r).abs() < 0.25,
            "{strategy:?} stage {i}: sim utilization {s:.3} vs executed {r:.3}"
        );
    }

    // 3) the monitor, fed the executed per-stage times, sees a pipeline
    //    that tracks the prediction. One window can never fire (patience
    //    gates repartitioning), so feed a sustained run of windows — well
    //    past the monitor's patience — and require Healthy throughout:
    //    had the executed times drifted beyond the threshold, the strikes
    //    would accumulate and this would return Repartition.
    let mut monitor = Monitor::new(cost.stage_secs.clone());
    let observed = real.stage_mean_busy();
    for window in 0..10 {
        assert_eq!(
            monitor.observe(&observed),
            MonitorVerdict::Healthy,
            "executed stage times drifted from the cost model's prediction \
             (window {window}, observed {observed:?}, predicted {:?})",
            cost.stage_secs
        );
    }
}

// Everything wall-clock runs inside ONE #[test] so the sleep-based worker
// threads never compete with each other for cores (cargo test runs tests
// of one binary on parallel threads; co-scheduling sleepy pipelines skews
// wall clocks on small CI runners).
#[test]
fn executed_pipeline_matches_des_and_beats_sequential_baseline() {
    cross_validate(Strategy::TwoTees, 40);
    cross_validate(Strategy::Proposed, 40);
    // single stage: completion must be ≈ n × service, in both engines
    cross_validate(Strategy::OneTee, 30);

    // and the paper's core claim, executed: pipelining the chunk through
    // the 2-TEE placement completes it faster than the 1-TEE baseline
    let prof = ModelProfile::millis_demo();
    let cm = CostModel::paper(&prof);
    let frames = 30u64;
    let run = |strategy: Strategy| {
        let p = plan(strategy, &cm, frames);
        let cost = cm.cost(&p.placement);
        let pipe =
            Pipeline::synthetic(cm.topology(), &p.placement, &cost, PipelineConfig::default());
        let feed = (0..frames).map(|_| FrameIn { stream: 0, payload: vec![0u8; 64] });
        pipe.run(feed, |_| {}).expect("pipeline run").completion_secs
    };
    let one = run(Strategy::OneTee);
    let two = run(Strategy::TwoTees);
    assert!(
        two < one,
        "2-TEE pipeline ({two:.3}s) did not beat the 1-TEE baseline ({one:.3}s)"
    );
    // the speedup should be material, not within-noise (period halves, so
    // expect ≥ 1.5x here; the paper reports 1.8–2.3x for 2 TEEs)
    assert!(one / two > 1.5, "speedup only {:.2}x", one / two);
}
