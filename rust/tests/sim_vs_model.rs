//! Cross-validation: the closed-form pipeline cost model (paper Eq. 1/2)
//! vs the discrete-event simulator, over every model and strategy — and
//! property-based over random stage structures.

use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::MODEL_NAMES;
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::profiler::calibrated_profile;
use serdab::profiler::devices::EpcModel;
use serdab::profiler::{DeviceKind, DeviceProfile, ModelProfile};
use serdab::sim::{simulate, SimConfig};
use serdab::util::prop;

#[test]
fn des_matches_closed_form_for_all_models_and_strategies() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(dir).unwrap();
    for name in MODEL_NAMES {
        let model = man.model(name).unwrap();
        let profile = calibrated_profile(model);
        let cm = CostModel::paper(&profile);
        for strat in Strategy::ALL {
            let p = plan(strat, &cm, 1000);
            let predicted = p.cost.chunk_secs(1000);
            let rep = simulate(&cm, &p.placement, &SimConfig { frames: 1000, ..Default::default() });
            let err = (rep.completion_secs - predicted).abs() / predicted;
            assert!(
                err < 0.02,
                "{name}/{strat:?}: DES {} vs model {predicted} (err {err:.3})",
                rep.completion_secs
            );
        }
    }
}

/// Random synthetic profiles: the DES must match the closed form for any
/// stage-time structure, not just the calibrated zoo.
#[test]
fn prop_des_matches_closed_form_on_random_profiles() {
    use serdab::placement::{Placement, Stage};
    use serdab::topology::Topology;

    let topo = Topology::paper_testbed();
    let tee1 = topo.require("TEE1").unwrap();
    let tee2 = topo.require("TEE2").unwrap();
    let gpu2 = topo.require("GPU2").unwrap();
    let gen = prop::pair(
        prop::vec_of(|| prop::f64_in(0.01, 2.0), 3, 9),
        prop::pair(prop::usize_in(1, 2), prop::usize_in(0, 1_000_000)),
    );
    prop::forall("des-matches-model", &gen, 25, |(tee_secs, (cuts, bytes))| {
        let m = tee_secs.len();
        let profile = ModelProfile {
            model: "rand".into(),
            m,
            cpu: DeviceProfile {
                kind: DeviceKind::UntrustedCpu,
                block_secs: tee_secs.iter().map(|s| s * 0.3).collect(),
            },
            gpu: DeviceProfile {
                kind: DeviceKind::Gpu,
                block_secs: tee_secs.iter().map(|s| s * 0.05).collect(),
            },
            tee: DeviceProfile { kind: DeviceKind::Tee, block_secs: tee_secs.clone() },
            param_bytes: vec![0; m],
            peak_act_bytes: vec![0; m],
            cut_bytes: vec![*bytes as u64; m],
            in_res: (0..m).map(|i| if i < m / 2 { 224 } else { 14 }).collect(),
            epc: EpcModel::default(),
        };
        let cm = CostModel::new(&profile, topo.clone());
        // placement: split at 1..m across TEE1/TEE2(/GPU for 3 stages)
        let cut1 = (1 + (*cuts % (m - 1).max(1))).min(m - 1);
        let placement = if m > cut1 + 1 && cuts % 2 == 1 {
            Placement {
                stages: vec![
                    Stage { resource: tee1, range: 0..cut1 },
                    Stage { resource: tee2, range: cut1..cut1 + 1 },
                    Stage { resource: gpu2, range: cut1 + 1..m },
                ],
            }
        } else {
            Placement {
                stages: vec![
                    Stage { resource: tee1, range: 0..cut1 },
                    Stage { resource: tee2, range: cut1..m },
                ],
            }
        };
        let n = 400u64;
        let predicted = cm.cost(&placement).chunk_secs(n);
        let rep = simulate(&cm, &placement, &SimConfig { frames: n, ..Default::default() });
        let err = (rep.completion_secs - predicted).abs() / predicted;
        if err < 0.03 {
            Ok(())
        } else {
            Err(format!(
                "stages {:?}: DES {} vs model {predicted}",
                tee_secs, rep.completion_secs
            ))
        }
    });
}

#[test]
fn paced_arrival_reduces_latency_not_throughput_below_capacity() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let man = load_manifest(dir).unwrap();
    let model = man.model("googlenet").unwrap();
    let profile = calibrated_profile(model);
    let cm = CostModel::paper(&profile);
    let p = plan(Strategy::TwoTees, &cm, 500);

    let burst = simulate(&cm, &p.placement, &SimConfig { frames: 200, ..Default::default() });
    let paced = simulate(
        &cm,
        &p.placement,
        &SimConfig { frames: 200, arrival_secs: p.cost.period_secs * 1.1, queue_cap: 4 },
    );
    assert!(paced.mean_latency() < burst.mean_latency());
}
