//! Acceptance for the data-driven topology API: a non-paper resource
//! graph (4 edge devices, one enclave each, plus an offload GPU) must
//! solve, simulate, and serve end-to-end — the scenario class the
//! hardcoded five-resource testbed could never express.
//!
//! The serving side uses the synthetic pipeline (workers execute the cost
//! model's service times for real) so the test runs without model
//! artifacts; with artifacts present, it additionally deploys a real
//! 4-enclave partition through the attested coordinator path.

use serdab::coordinator::{Deployment, ResourceManager};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::DELTA_RESOLUTION;
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::{Placement, Stage};
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::runtime::pipeline::{FrameIn, Pipeline, PipelineConfig};
use serdab::sim::{simulate, SimConfig};
use serdab::topology::{LinkParams, Topology};
use serdab::video::{SceneKind, VideoSource};

/// 4 edge devices with one enclave each on a fast LAN, plus a GPU and a
/// CPU on the last device — a DistPrivacy-style surveillance cluster.
fn quad_topology() -> Topology {
    Topology::builder("quad-edge")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .resource("G3", DeviceKind::Gpu, 3)
        .resource("C3", DeviceKind::UntrustedCpu, 3)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap()
}

#[test]
fn quad_cluster_solves_simulates_and_serves() {
    let prof = ModelProfile::millis_demo();
    let topo = quad_topology();
    let cm = CostModel::new(&prof, topo.clone());
    let frames = 40u64;

    // --- solve: the 4-TEE spine actually gets used ----------------------
    let two = plan(Strategy::TwoTees, &cm, frames);
    two.placement.validate(&topo, prof.m).unwrap();
    assert!(
        two.placement.stages.len() >= 3,
        "fast links should spread the chain over ≥3 enclaves: {}",
        two.placement.describe(&topo)
    );
    let proposed = plan(Strategy::Proposed, &cm, frames);
    proposed.placement.validate(&topo, prof.m).unwrap();
    assert!(proposed.placement.satisfies_privacy(&topo, &prof.in_res, DELTA_RESOLUTION));
    let one = plan(Strategy::OneTee, &cm, frames);
    let speedup = one.cost.chunk_secs(frames) / proposed.cost.chunk_secs(frames);
    assert!(speedup > 2.0, "multi-enclave speedup only {speedup:.2}x");

    // --- simulate: the DES agrees with the closed form on this graph ----
    for p in [&two, &proposed] {
        let des = simulate(&cm, &p.placement, &SimConfig { frames, ..Default::default() });
        let predicted = p.cost.chunk_secs(frames);
        let err = (des.completion_secs - predicted).abs() / predicted;
        assert!(
            err < 0.02,
            "{}: DES {} vs model {predicted}",
            p.placement.describe(&topo),
            des.completion_secs
        );
    }

    // --- serve: executed pipeline (real threads, queues, backpressure) --
    let cost = cm.cost(&proposed.placement);
    let des = simulate(&cm, &proposed.placement, &SimConfig { frames, ..Default::default() });
    let pipe = Pipeline::synthetic(&topo, &proposed.placement, &cost, PipelineConfig::default());
    let feed = (0..frames).map(|_| FrameIn { stream: 0, payload: vec![0u8; 64] });
    let rep = pipe.run(feed, |_| {}).expect("pipeline run");
    assert_eq!(rep.frames, frames, "frames lost in the executed pipeline");
    let err = (rep.completion_secs - des.completion_secs).abs() / des.completion_secs;
    assert!(
        err < 0.15,
        "executed {:.4}s vs DES {:.4}s ({:.1}% off) for {}",
        rep.completion_secs,
        des.completion_secs,
        err * 100.0,
        proposed.placement.describe(&topo)
    );
    // worker labels carry the topology's resource names
    let labels: Vec<&str> = rep.workers.iter().map(|w| w.label.as_str()).collect();
    assert!(
        labels.iter().any(|l| l.starts_with("T0[")),
        "stage labels should name topology resources: {labels:?}"
    );
}

#[test]
fn quad_cluster_deploys_real_partitions_when_artifacts_exist() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(dir).unwrap();
    let model = "squeezenet";
    let info = man.model(model).unwrap();
    let m = info.m();
    assert!(m >= 4, "squeezenet chain too short to split 4 ways");

    let topo = quad_topology();
    let rm = ResourceManager::for_topology(&topo);
    // an explicit 4-enclave split — a placement shape the old five-const
    // graph could not even name
    let cuts = [m / 4, m / 2, 3 * m / 4];
    let placement = Placement {
        stages: vec![
            Stage { resource: topo.require("T0").unwrap(), range: 0..cuts[0] },
            Stage { resource: topo.require("T1").unwrap(), range: cuts[0]..cuts[1] },
            Stage { resource: topo.require("T2").unwrap(), range: cuts[1]..cuts[2] },
            Stage { resource: topo.require("T3").unwrap(), range: cuts[2]..m },
        ],
    };
    placement.validate(&topo, m).unwrap();

    let dep = Deployment::deploy(&man, &rm, model, &placement, Some(1e9), 4).unwrap();
    let mut cam = VideoSource::new(SceneKind::Street, 17);
    let frames: Vec<_> = (0..4).map(|_| cam.next_frame()).collect();
    let rep = dep.run_stream(frames.into_iter()).unwrap();
    assert_eq!(rep.frames, 4);
    assert!(rep.output_checksum.is_finite());
    // four compute stages + three links between distinct hosts
    let stages = rep.workers.iter().filter(|w| w.label.contains('[')).count();
    assert_eq!(stages, 4, "expected 4 enclave stages");
}
