//! GEMM-vs-naive parity property tests: the blocked GEMM compute core
//! (im2col conv, column-split dense, channel-inner dwconv) must agree
//! with the retained pre-GEMM scalar kernels over randomized shapes —
//! odd H/W, stride 2, SAME/VALID padding, channel counts that are not
//! multiples of the register-tile sizes — plus a worker-count
//! determinism check: `SERDAB_THREADS=1` and `=4` (pinned through
//! `Scratch::with_threads`, same mechanism) must produce bit-identical
//! outputs, because every output element is computed by exactly one
//! worker with the same accumulation order. The resident compute pool
//! gets the same treatment: pool sizes {1, 2, 4} must be bit-invisible,
//! and pooled dispatch must match the retained scoped-spawn oracle
//! (`pool::run_scoped`) byte for byte on real GEMM row chunks.

use serdab::runtime::backend::reference::ops::{self, naive};
use serdab::runtime::backend::reference::zoo::Pad;
use serdab::runtime::{Scratch, Tensor};
use serdab::util::rng::Rng;

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    Tensor::new(shape.to_vec(), data).unwrap()
}

/// Reordering the f32 reduction moves results by ~K·ε; inputs are in
/// [-1, 1] and K ≤ a few hundred, so 1e-4 has orders of magnitude slack
/// while still catching any real indexing bug.
const TOL: f32 = 1e-4;

#[test]
fn conv2d_gemm_matches_naive_over_random_shapes() {
    let mut rng = Rng::new(0xc011ec7);
    // (h, w, cin, k, cout, stride, pad) — deliberately awkward: odd
    // spatial dims, stride 2, channels not multiples of MR/NR
    let pads = [Pad::Same, Pad::Valid];
    for case in 0..24 {
        let k = [1usize, 3, 5][rng.range(0, 3)];
        let h = rng.range(k, k + 11); // ≥ k so VALID stays legal
        let w = rng.range(k, k + 11);
        let cin = rng.range(1, 21);
        let cout = rng.range(1, 37);
        let stride = rng.range(1, 3);
        let pad = &pads[rng.range(0, 2)];
        let relu = rng.bool(0.5);
        let n = rng.range(1, 3);

        let x = rand_tensor(&mut rng, &[n, h, w, cin]);
        let wt = rand_tensor(&mut rng, &[k, k, cin, cout]);
        let b = rand_tensor(&mut rng, &[cout]);

        let fast = ops::conv2d(&x, &wt, &b, stride, pad, relu).unwrap();
        let slow = naive::conv2d(&x, &wt, &b, stride, pad, relu).unwrap();
        assert_eq!(fast.shape, slow.shape, "case {case}: shape mismatch");
        let diff = fast.max_abs_diff(&slow);
        assert!(
            diff < TOL,
            "case {case} (h={h} w={w} cin={cin} k={k} cout={cout} s={stride} {pad:?} relu={relu} n={n}): diff {diff}"
        );
    }
}

#[test]
fn conv2d_explicit_padding_matches_naive() {
    // the zoo's alexnet entry conv uses Pad::Explicit{2,2,2,2}
    let mut rng = Rng::new(0xa1e);
    let pad = Pad::Explicit { top: 2, bottom: 2, left: 2, right: 2 };
    let x = rand_tensor(&mut rng, &[1, 11, 13, 3]);
    let wt = rand_tensor(&mut rng, &[5, 5, 3, 8]);
    let b = rand_tensor(&mut rng, &[8]);
    for stride in [1usize, 2, 4] {
        let fast = ops::conv2d(&x, &wt, &b, stride, &pad, true).unwrap();
        let slow = naive::conv2d(&x, &wt, &b, stride, &pad, true).unwrap();
        assert_eq!(fast.shape, slow.shape);
        let diff = fast.max_abs_diff(&slow);
        assert!(diff < TOL, "stride {stride}: diff {diff}");
    }
}

#[test]
fn dwconv2d_matches_naive_over_random_shapes() {
    let mut rng = Rng::new(0xd3adbeef);
    for case in 0..16 {
        let k = [1usize, 3, 5][rng.range(0, 3)];
        let h = rng.range(k, k + 9);
        let w = rng.range(k, k + 9);
        let c = rng.range(1, 35);
        let stride = rng.range(1, 3);
        let pad = if rng.bool(0.5) { Pad::Same } else { Pad::Valid };
        let relu = rng.bool(0.5);

        let x = rand_tensor(&mut rng, &[1, h, w, c]);
        let wt = rand_tensor(&mut rng, &[k, k, c]);
        let b = rand_tensor(&mut rng, &[c]);

        let fast = ops::dwconv2d(&x, &wt, &b, stride, &pad, relu).unwrap();
        let slow = naive::dwconv2d(&x, &wt, &b, stride, &pad, relu).unwrap();
        assert_eq!(fast.shape, slow.shape);
        // identical tap order → the channel-inner rewrite is bit-exact
        let diff = fast.max_abs_diff(&slow);
        assert!(diff == 0.0, "case {case}: dwconv diff {diff}");
    }
}

#[test]
fn pool2d_matches_naive_over_random_shapes() {
    let mut rng = Rng::new(0x9001);
    for _ in 0..12 {
        let k = [2usize, 3][rng.range(0, 2)];
        let h = rng.range(k, k + 8);
        let w = rng.range(k, k + 8);
        let c = rng.range(1, 20);
        let stride = rng.range(1, 3);
        let pad = if rng.bool(0.5) { Pad::Same } else { Pad::Valid };
        let max = rng.bool(0.5);
        let x = rand_tensor(&mut rng, &[1, h, w, c]);
        let fast = ops::pool2d(&x, k, stride, max, &pad).unwrap();
        let slow = naive::pool2d(&x, k, stride, max, &pad).unwrap();
        assert_eq!(fast.shape, slow.shape);
        assert!(fast.max_abs_diff(&slow) == 0.0, "pool must be bit-exact");
    }
}

#[test]
fn dense_matches_naive_over_random_shapes() {
    let mut rng = Rng::new(0xfeed);
    for case in 0..12 {
        let fin = rng.range(1, 300);
        let fout = rng.range(1, 70);
        let n = [1usize, 1, 3][rng.range(0, 3)]; // mostly batch 1 (serving)
        let relu = rng.bool(0.5);
        let x = rand_tensor(&mut rng, &[n, fin]);
        let w = rand_tensor(&mut rng, &[fin, fout]);
        let b = rand_tensor(&mut rng, &[fout]);
        let fast = ops::dense(&x, &w, &b, relu).unwrap();
        let slow = naive::dense(&x, &w, &b, relu).unwrap();
        assert_eq!(fast.shape, slow.shape);
        let diff = fast.max_abs_diff(&slow);
        assert!(diff < TOL, "case {case} (fin={fin} fout={fout} n={n}): diff {diff}");
    }
}

#[test]
fn thread_count_is_bit_invisible() {
    // big enough to clear the parallelism threshold (~21 MFLOP conv,
    // ~4 MFLOP dense/dwconv), so the 4-worker run really splits rows
    let mut rng = Rng::new(0x7117);
    let x = rand_tensor(&mut rng, &[1, 24, 24, 16]);
    let w = rand_tensor(&mut rng, &[3, 3, 16, 32]);
    let b = rand_tensor(&mut rng, &[32]);
    let xd = rand_tensor(&mut rng, &[1, 2048]);
    let wd = rand_tensor(&mut rng, &[2048, 768]);
    let bd = rand_tensor(&mut rng, &[768]);
    let xw = rand_tensor(&mut rng, &[1, 56, 56, 64]);
    let ww = rand_tensor(&mut rng, &[3, 3, 64]);
    let bw = rand_tensor(&mut rng, &[64]);

    let mut s1 = Scratch::with_threads(1);
    let mut s4 = Scratch::with_threads(4);

    let c1 = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut s1).unwrap();
    let c4 = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut s4).unwrap();
    assert_eq!(c1.to_le_bytes(), c4.to_le_bytes(), "conv must be thread-count invariant");

    let d1 = ops::dense_scratch(&xd, &wd, &bd, false, &mut s1).unwrap();
    let d4 = ops::dense_scratch(&xd, &wd, &bd, false, &mut s4).unwrap();
    assert_eq!(d1.to_le_bytes(), d4.to_le_bytes(), "dense must be thread-count invariant");

    let w1 = ops::dwconv2d_scratch(&xw, &ww, &bw, 1, &Pad::Same, true, &mut s1).unwrap();
    let w4 = ops::dwconv2d_scratch(&xw, &ww, &bw, 1, &Pad::Same, true, &mut s4).unwrap();
    assert_eq!(w1.to_le_bytes(), w4.to_le_bytes(), "dwconv must be thread-count invariant");

    // 1×1 fast path (no im2col) at a split-unfriendly size, big enough
    // to clear the parallelism threshold (2·M·Cin·Cout ≈ 5.6 MFLOP)
    let x1 = rand_tensor(&mut rng, &[1, 49, 47, 25]);
    let k1 = rand_tensor(&mut rng, &[1, 1, 25, 49]);
    let b1 = rand_tensor(&mut rng, &[49]);
    let a1 = ops::conv2d_scratch(&x1, &k1, &b1, 1, &Pad::Same, false, &mut s1).unwrap();
    let a4 = ops::conv2d_scratch(&x1, &k1, &b1, 1, &Pad::Same, false, &mut s4).unwrap();
    assert_eq!(a1.to_le_bytes(), a4.to_le_bytes(), "1×1 path must be thread-count invariant");
}

#[test]
fn pool_size_is_bit_invisible() {
    // the resident pool must be as invisible as the thread count: pool
    // sizes {1, 2, 4} (1 never touches the queue) produce identical bytes
    // on a conv and a dense big enough to clear the parallel threshold
    let mut rng = Rng::new(0x9007a);
    let x = rand_tensor(&mut rng, &[1, 24, 24, 16]);
    let w = rand_tensor(&mut rng, &[3, 3, 16, 32]);
    let b = rand_tensor(&mut rng, &[32]);
    let xd = rand_tensor(&mut rng, &[1, 2048]);
    let wd = rand_tensor(&mut rng, &[2048, 768]);
    let bd = rand_tensor(&mut rng, &[768]);

    let mut conv_outs = Vec::new();
    let mut dense_outs = Vec::new();
    for threads in [1usize, 2, 4] {
        let mut s = Scratch::with_threads(threads);
        conv_outs
            .push(ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut s).unwrap().to_le_bytes());
        dense_outs.push(ops::dense_scratch(&xd, &wd, &bd, false, &mut s).unwrap().to_le_bytes());
    }
    assert_eq!(conv_outs[0], conv_outs[1], "conv: pool size 2 changed bytes");
    assert_eq!(conv_outs[0], conv_outs[2], "conv: pool size 4 changed bytes");
    assert_eq!(dense_outs[0], dense_outs[1], "dense: pool size 2 changed bytes");
    assert_eq!(dense_outs[0], dense_outs[2], "dense: pool size 4 changed bytes");
}

#[test]
fn pooled_dispatch_matches_scoped_dispatch_on_gemm_rows() {
    // identical chunk bodies — real GEMM calls over disjoint output-row
    // ranges — through the resident pool and through the retained
    // scoped-spawn oracle: the dispatch mechanism must not change a bit
    use serdab::runtime::backend::reference::gemm;
    use serdab::runtime::pool::{self, SendPtr};

    let mut rng = Rng::new(0x5ca1e);
    let (m, k, n) = (64usize, 37usize, 33usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let bm: Vec<f32> = (0..k * n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let bias: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
    let chunks = 4usize;
    let per = (m + chunks - 1) / chunks;

    let run_with = |dispatch: &dyn Fn(usize, &(dyn Fn(usize) + Sync))| -> Vec<u32> {
        let mut c = vec![0f32; m * n];
        let base = SendPtr(c.as_mut_ptr());
        dispatch(chunks, &|ci| {
            let r0 = ci * per;
            let r1 = ((ci + 1) * per).min(m);
            if r0 >= r1 {
                return;
            }
            // SAFETY: chunk row ranges are disjoint, and the dispatcher
            // guarantees each chunk index runs exactly once.
            let mine = unsafe { std::slice::from_raw_parts_mut(base.0.add(r0 * n), (r1 - r0) * n) };
            gemm::gemm_bias(r1 - r0, k, n, &a[r0 * k..r1 * k], &bm, Some(&bias), true, mine);
        });
        c.iter().map(|v| v.to_bits()).collect()
    };

    let pooled = run_with(&|nc, f| pool::global().run(nc, f));
    let scoped = run_with(&|nc, f| pool::run_scoped(nc, f));
    assert_eq!(pooled, scoped, "pooled dispatch diverged from the scoped oracle");
}

#[test]
fn scratch_reuse_does_not_corrupt_results() {
    // run two different convs back to back through ONE arena; the second
    // result must be independent of the first's stale buffers
    let mut rng = Rng::new(0xab);
    let mut scratch = Scratch::with_threads(2);
    let xa = rand_tensor(&mut rng, &[1, 9, 9, 7]);
    let wa = rand_tensor(&mut rng, &[3, 3, 7, 11]);
    let ba = rand_tensor(&mut rng, &[11]);
    let xb = rand_tensor(&mut rng, &[1, 6, 5, 3]);
    let wb = rand_tensor(&mut rng, &[5, 5, 3, 2]);
    let bb = rand_tensor(&mut rng, &[2]);

    let first = ops::conv2d_scratch(&xa, &wa, &ba, 1, &Pad::Same, true, &mut scratch).unwrap();
    scratch.give(first);
    let second = ops::conv2d_scratch(&xb, &wb, &bb, 2, &Pad::Same, false, &mut scratch).unwrap();
    let clean = naive::conv2d(&xb, &wb, &bb, 2, &Pad::Same, false).unwrap();
    assert_eq!(second.shape, clean.shape);
    assert!(second.max_abs_diff(&clean) < TOL);
}
