//! Steady-state zero-allocation guarantee (DESIGN.md §14): after one
//! warm-up frame has sized every pool and staging buffer, the per-frame
//! hot path — conv/dwconv/dense/pool through the scratch arena (batch-1
//! AND a stacked micro-batch, per DESIGN.md §16's sizing rule), a full
//! reference-block forward (including a parallel merge), GCM
//! seal+open, epoch-carrying channel records sealed/opened into reused
//! buffers (measured *after* a re-key, in the current+previous-key
//! regime every long-lived deployment serves in), and coalesced
//! framing — performs **zero** heap allocations.
//!
//! A counting `#[global_allocator]` (test-binary only) measures it
//! directly. Everything runs inside ONE test function so parallel test
//! threads cannot pollute the counter. The single-worker section pins
//! `Scratch::with_threads(1)`; a second section then proves the
//! *pooled* frame path — a conv big enough to fan out across the
//! resident compute pool (DESIGN.md §20), plus a prepacked-weight conv
//! reusing a cached packed-B panel — is also allocation-free once the
//! pool's workers are spawned and its chunk queue has its capacity:
//! dispatch is a queue push into retained storage and the completion
//! latch lives on the submitter's stack.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Cursor;
use std::sync::atomic::{AtomicU64, Ordering};

use serdab::crypto::channel::Channel;
use serdab::crypto::gcm::AesGcm;
use serdab::model::{BlockInfo, ModelInfo};
use serdab::net::framing::{read_frame_into, FrameType, FrameWriter};
use serdab::runtime::backend::reference::ops;
use serdab::runtime::backend::reference::zoo::{self, Pad};
use serdab::runtime::backend::reference::ReferenceBackend;
use serdab::runtime::{Backend, BlockRunner, Scratch, Tensor};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; only adds a relaxed
// counter bump on the allocating entry points.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

fn rand_tensor(seed: u64, shape: &[usize]) -> Tensor {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s >> 40) as f32 / (1u64 << 24) as f32) - 0.5
        })
        .collect();
    Tensor::new(shape.to_vec(), data).unwrap()
}

/// A loaded squeezenet fire block (exercises conv, the parallel concat
/// merge, and the params walk) built from a temp params file — the same
/// synthetic-manifest trick `backend_parity.rs` uses.
fn fire_runner() -> Box<dyn BlockRunner> {
    let dir = std::env::temp_dir().join("serdab_alloc_fire");
    std::fs::create_dir_all(&dir).unwrap();
    let params = [
        rand_tensor(1, &[1, 1, 1, 2]),
        rand_tensor(2, &[2]),
        rand_tensor(3, &[1, 1, 2, 1]),
        rand_tensor(4, &[1]),
        rand_tensor(5, &[3, 3, 2, 1]),
        rand_tensor(6, &[1]),
    ];
    let mut bytes = Vec::new();
    for t in &params {
        bytes.extend_from_slice(&t.to_le_bytes());
    }
    std::fs::write(dir.join("fire2.params.bin"), bytes).unwrap();

    let defs = zoo::arch_blocks("squeezenet").unwrap();
    let blocks: Vec<BlockInfo> = defs
        .iter()
        .enumerate()
        .map(|(i, d)| {
            let mut b = BlockInfo {
                idx: i,
                name: d.name.to_string(),
                hlo: String::new(),
                params: String::new(),
                golden: String::new(),
                params_sha256: String::new(),
                golden_sha256: String::new(),
                param_shapes: vec![],
                param_floats: 0,
                in_shape: vec![],
                out_shape: vec![],
                in_res: 1,
                out_res: 1,
                flops_full: 1,
                param_bytes_full: 1,
                out_bytes_full: 1,
                act_bytes_full: 1,
                peak_act_bytes_full: 1,
                n_ops: 1,
                kernel: None,
            };
            if i == 1 {
                b.params = "fire2.params.bin".into();
                b.param_shapes = params.iter().map(|p| p.shape.clone()).collect();
                b.param_floats = params.iter().map(|p| p.len() as u64).sum();
                b.in_shape = vec![1, 4, 4, 1];
                b.out_shape = vec![1, 4, 4, 2];
            }
            b
        })
        .collect();
    let model = ModelInfo {
        name: "squeezenet".to_string(),
        tiny_width: 0.125,
        tiny_classes: 10,
        golden_input: String::new(),
        total_flops_full: 1,
        model_bytes_full: 1,
        blocks,
    };
    ReferenceBackend.load_block(&dir, &model, 1).unwrap()
}

#[test]
fn steady_state_frame_path_allocates_nothing() {
    // ---- setup (allocations here are fine) ---------------------------
    let mut scratch = Scratch::with_threads(1);
    let x = rand_tensor(10, &[1, 8, 9, 5]);
    let w = rand_tensor(11, &[3, 3, 5, 7]);
    let b = rand_tensor(12, &[7]);
    let xw = rand_tensor(13, &[1, 7, 7, 6]);
    let ww = rand_tensor(14, &[3, 3, 6]);
    let bw = rand_tensor(15, &[6]);
    let xd = rand_tensor(16, &[1, 40]);
    let wd = rand_tensor(17, &[40, 23]);
    let bd = rand_tensor(18, &[23]);
    // the micro-batched shapes: 3 frames stacked along dim 0, same
    // weights — the pipeline's coalesced path through the same arena
    let xb = rand_tensor(20, &[3, 8, 9, 5]);
    let xdb = rand_tensor(21, &[3, 40]);

    let runner = fire_runner();
    let fire_in = rand_tensor(19, &[1, 4, 4, 1]);

    let gcm = AesGcm::new(b"alloc-bench-key!");
    let mut gcm_buf = vec![9u8; 4096];

    let mut chan_a = Channel::new(b"alloc-secret", true);
    let mut chan_b = Channel::new(b"alloc-secret", false);
    // rotate once before measuring: steady state must hold while the
    // receiver still holds current + previous epoch keys (the post-re-key
    // regime every long-lived deployment serves in)
    chan_a.rekey(b"alloc-secret-2", 1);
    chan_b.rekey(b"alloc-secret-2", 1);
    let payload = vec![5u8; 2048];
    let mut rec_buf = Vec::new();
    let mut plain_buf = Vec::new();

    let mut fw = FrameWriter::new(std::io::sink());
    let mut frame_bytes = Vec::new();
    serdab::net::framing::encode_frame_into(&mut frame_bytes, FrameType::Data, &payload).unwrap();
    let mut read_buf = Vec::new();

    // one steady-state "frame" over every hot-path primitive
    let mut frame = |scratch: &mut Scratch| {
        let c = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, scratch).unwrap();
        scratch.give(c);
        let c = ops::dwconv2d_scratch(&xw, &ww, &bw, 2, &Pad::Same, true, scratch).unwrap();
        scratch.give(c);
        let c = ops::dense_scratch(&xd, &wd, &bd, true, scratch).unwrap();
        scratch.give(c);
        // batched path: a 3-frame micro-batch must be as alloc-free as
        // batch 1 once the pool is sized for the max batch
        let c = ops::conv2d_scratch(&xb, &w, &b, 1, &Pad::Same, true, scratch).unwrap();
        scratch.give(c);
        let c = ops::dense_scratch(&xdb, &wd, &bd, true, scratch).unwrap();
        scratch.give(c);
        let c = ops::pool2d_scratch(&x, 2, 2, true, &Pad::Valid, scratch).unwrap();
        scratch.give(c);
        let c = runner.run_scratch(&fire_in, scratch).unwrap();
        scratch.give(c);

        let tag = gcm.seal(&[1u8; 12], b"aad", &mut gcm_buf);
        gcm.open(&[1u8; 12], b"aad", &mut gcm_buf, &tag).unwrap();

        chan_a.tx.seal_record_into(&payload, &mut rec_buf).unwrap();
        chan_b.rx.open_record_into(&rec_buf, &mut plain_buf).unwrap();

        fw.send(FrameType::Data, &payload).unwrap();
        let ty = read_frame_into(&mut Cursor::new(&frame_bytes[..]), &mut read_buf).unwrap();
        assert_eq!(ty, FrameType::Data);
    };

    // ---- warm up: size every pool and staging buffer -----------------
    frame(&mut scratch);
    frame(&mut scratch);

    // ---- measure: a steady-state frame must allocate nothing ---------
    let before = allocs();
    frame(&mut scratch);
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "steady-state frame path allocated {} times (conv/dense/crypt/framing must be alloc-free)",
        after - before
    );

    // ---- pooled + packed-B section -----------------------------------
    // A conv over the parallel threshold so it really fans out across
    // the resident pool, and the same conv through a cached packed-B
    // panel. Warm-up pays worker spawns and the queue's first growth;
    // steady state must then be zero allocations end to end.
    let xp = rand_tensor(30, &[1, 28, 28, 32]);
    let wp = rand_tensor(31, &[3, 3, 32, 64]);
    let bp = rand_tensor(32, &[64]);
    let pb = serdab::runtime::backend::reference::gemm::pack_cache().get_or_pack(
        3 * 3 * 32,
        64,
        &wp.data,
    );
    let mut pooled = Scratch::with_threads(2);
    let mut pooled_frame = |scratch: &mut Scratch| {
        let c = ops::conv2d_scratch(&xp, &wp, &bp, 1, &Pad::Same, true, scratch).unwrap();
        scratch.give(c);
        let c = ops::conv2d_packed_scratch(
            &xp,
            &wp,
            &bp,
            1,
            &Pad::Same,
            true,
            Some(pb.as_ref()),
            scratch,
        )
        .unwrap();
        scratch.give(c);
    };
    pooled_frame(&mut pooled);
    pooled_frame(&mut pooled);

    let before = allocs();
    pooled_frame(&mut pooled);
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "pooled steady-state frame path allocated {} times (pool dispatch + packed-B reuse must be alloc-free)",
        after - before
    );
}
