//! Placement::validate edge cases — the deployment gate every placement
//! passes through before attestation/key release, so its rejection
//! surface (empty stage, gap, overlap, duplicate resource, bad coverage)
//! must be exact.

use serdab::placement::{Placement, Stage, E1_CPU, E2_CPU, E2_GPU, TEE1, TEE2};

fn p(stages: Vec<(serdab::placement::Resource, std::ops::Range<usize>)>) -> Placement {
    Placement {
        stages: stages
            .into_iter()
            .map(|(resource, range)| Stage { resource, range })
            .collect(),
    }
}

#[test]
fn accepts_single_and_full_multistage_coverage() {
    assert!(Placement::single(TEE1, 10).validate(10).is_ok());
    assert!(p(vec![(TEE1, 0..1), (TEE2, 1..2)]).validate(2).is_ok());
    let five = p(vec![
        (TEE1, 0..2),
        (E1_CPU, 2..4),
        (TEE2, 4..6),
        (E2_CPU, 6..8),
        (E2_GPU, 8..12),
    ]);
    assert!(five.validate(12).is_ok());
}

#[test]
fn rejects_no_stages_at_all() {
    let err = Placement { stages: vec![] }.validate(5).unwrap_err();
    assert!(err.contains("no stages"), "{err}");
}

#[test]
fn rejects_empty_stage() {
    // an empty range on a resource is not a real pipeline position
    let err = p(vec![(TEE1, 0..0), (TEE2, 0..5)]).validate(5).unwrap_err();
    assert!(err.contains("empty stage"), "{err}");
    assert!(err.contains("TEE1"), "{err}");
    // empty stage in the middle
    let err = p(vec![(TEE1, 0..3), (E2_GPU, 3..3), (TEE2, 3..5)])
        .validate(5)
        .unwrap_err();
    assert!(err.contains("empty stage"), "{err}");
}

#[test]
fn rejects_gap_and_overlap() {
    let err = p(vec![(TEE1, 0..2), (TEE2, 3..6)]).validate(6).unwrap_err();
    assert!(err.contains("gap/overlap at block 2"), "{err}");
    let err = p(vec![(TEE1, 0..4), (TEE2, 3..6)]).validate(6).unwrap_err();
    assert!(err.contains("gap/overlap"), "{err}");
    // stages out of order are a gap at block 0's successor
    let err = p(vec![(TEE2, 3..6), (TEE1, 0..3)]).validate(6).unwrap_err();
    assert!(err.contains("gap/overlap"), "{err}");
}

#[test]
fn rejects_duplicate_resource() {
    // a resource cannot occupy two pipeline positions
    let err = p(vec![(TEE1, 0..3), (TEE1, 3..6)]).validate(6).unwrap_err();
    assert!(err.contains("used twice"), "{err}");
    let err = p(vec![(TEE1, 0..2), (TEE2, 2..4), (TEE1, 4..6)])
        .validate(6)
        .unwrap_err();
    assert!(err.contains("TEE1 used twice"), "{err}");
}

#[test]
fn rejects_wrong_total_coverage() {
    // undershoot: covers 0..4 of 6
    let err = p(vec![(TEE1, 0..4)]).validate(6).unwrap_err();
    assert!(err.contains("covers 0..4"), "{err}");
    // overshoot: covers 0..8 of 6
    let err = p(vec![(TEE1, 0..5), (TEE2, 5..8)]).validate(6).unwrap_err();
    assert!(err.contains("covers 0..8"), "{err}");
}

#[test]
fn zero_block_model_is_never_coverable() {
    assert!(Placement { stages: vec![] }.validate(0).is_err());
    assert!(p(vec![(TEE1, 0..1)]).validate(0).is_err());
}

#[test]
fn validity_is_a_precondition_of_privacy_check() {
    // satisfies_privacy only inspects untrusted stages; a valid placement
    // with the cut exactly at the δ crossing passes, one block earlier
    // fails — the C2 boundary is inclusive on the private side
    let in_res = [224, 56, 28, 20, 7, 1];
    let at_crossing = p(vec![(TEE1, 0..3), (E2_GPU, 3..6)]);
    assert!(at_crossing.validate(6).is_ok());
    assert!(at_crossing.satisfies_privacy(&in_res, 20)); // GPU first sees 20 ≤ δ
    let too_early = p(vec![(TEE1, 0..2), (E2_GPU, 2..6)]);
    assert!(too_early.validate(6).is_ok());
    assert!(!too_early.satisfies_privacy(&in_res, 20)); // GPU sees 28 > δ
}
