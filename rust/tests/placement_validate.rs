//! Placement::validate edge cases — the deployment gate every placement
//! passes through before attestation/key release, so its rejection
//! surface (foreign resource id, empty stage, gap, overlap, duplicate
//! resource, bad coverage) must be exact.

use serdab::placement::{Placement, ResourceId, Stage};
use serdab::topology::Topology;

fn topo() -> Topology {
    Topology::paper_testbed()
}

fn rid(topo: &Topology, name: &str) -> ResourceId {
    topo.require(name).unwrap()
}

fn p(topo: &Topology, stages: Vec<(&str, std::ops::Range<usize>)>) -> Placement {
    Placement {
        stages: stages
            .into_iter()
            .map(|(name, range)| Stage { resource: rid(topo, name), range })
            .collect(),
    }
}

#[test]
fn accepts_single_and_full_multistage_coverage() {
    let t = topo();
    assert!(Placement::single(rid(&t, "TEE1"), 10).validate(&t, 10).is_ok());
    assert!(p(&t, vec![("TEE1", 0..1), ("TEE2", 1..2)]).validate(&t, 2).is_ok());
    let five = p(
        &t,
        vec![
            ("TEE1", 0..2),
            ("E1", 2..4),
            ("TEE2", 4..6),
            ("E2", 6..8),
            ("GPU2", 8..12),
        ],
    );
    assert!(five.validate(&t, 12).is_ok());
}

#[test]
fn rejects_no_stages_at_all() {
    let t = topo();
    let err = Placement { stages: vec![] }.validate(&t, 5).unwrap_err();
    assert!(err.contains("no stages"), "{err}");
}

#[test]
fn rejects_foreign_resource_id() {
    let t = topo();
    let alien = Placement { stages: vec![Stage { resource: ResourceId(42), range: 0..5 }] };
    let err = alien.validate(&t, 5).unwrap_err();
    assert!(err.contains("not in topology"), "{err}");
}

#[test]
fn rejects_empty_stage() {
    let t = topo();
    // an empty range on a resource is not a real pipeline position
    let err = p(&t, vec![("TEE1", 0..0), ("TEE2", 0..5)]).validate(&t, 5).unwrap_err();
    assert!(err.contains("empty stage"), "{err}");
    assert!(err.contains("TEE1"), "{err}");
    // empty stage in the middle
    let err = p(&t, vec![("TEE1", 0..3), ("GPU2", 3..3), ("TEE2", 3..5)])
        .validate(&t, 5)
        .unwrap_err();
    assert!(err.contains("empty stage"), "{err}");
}

#[test]
fn rejects_gap_and_overlap() {
    let t = topo();
    let err = p(&t, vec![("TEE1", 0..2), ("TEE2", 3..6)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("gap/overlap at block 2"), "{err}");
    let err = p(&t, vec![("TEE1", 0..4), ("TEE2", 3..6)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("gap/overlap"), "{err}");
    // stages out of order are a gap at block 0's successor
    let err = p(&t, vec![("TEE2", 3..6), ("TEE1", 0..3)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("gap/overlap"), "{err}");
}

#[test]
fn rejects_duplicate_resource() {
    let t = topo();
    // a resource cannot occupy two pipeline positions
    let err = p(&t, vec![("TEE1", 0..3), ("TEE1", 3..6)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("used twice"), "{err}");
    let err = p(&t, vec![("TEE1", 0..2), ("TEE2", 2..4), ("TEE1", 4..6)])
        .validate(&t, 6)
        .unwrap_err();
    assert!(err.contains("TEE1 used twice"), "{err}");
}

#[test]
fn rejects_wrong_total_coverage() {
    let t = topo();
    // undershoot: covers 0..4 of 6
    let err = p(&t, vec![("TEE1", 0..4)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("covers 0..4"), "{err}");
    // overshoot: covers 0..8 of 6
    let err = p(&t, vec![("TEE1", 0..5), ("TEE2", 5..8)]).validate(&t, 6).unwrap_err();
    assert!(err.contains("covers 0..8"), "{err}");
}

#[test]
fn zero_block_model_is_never_coverable() {
    let t = topo();
    assert!(Placement { stages: vec![] }.validate(&t, 0).is_err());
    assert!(p(&t, vec![("TEE1", 0..1)]).validate(&t, 0).is_err());
}

#[test]
fn validity_is_a_precondition_of_privacy_check() {
    let t = topo();
    // satisfies_privacy only inspects untrusted stages; a valid placement
    // with the cut exactly at the δ crossing passes, one block earlier
    // fails — the C2 boundary is inclusive on the private side
    let in_res = [224, 56, 28, 20, 7, 1];
    let at_crossing = p(&t, vec![("TEE1", 0..3), ("GPU2", 3..6)]);
    assert!(at_crossing.validate(&t, 6).is_ok());
    assert!(at_crossing.satisfies_privacy(&t, &in_res, 20)); // GPU first sees 20 ≤ δ
    let too_early = p(&t, vec![("TEE1", 0..2), ("GPU2", 2..6)]);
    assert!(too_early.validate(&t, 6).is_ok());
    assert!(!too_early.satisfies_privacy(&t, &in_res, 20)); // GPU sees 28 > δ
}

#[test]
fn validates_against_non_paper_topologies() {
    use serdab::profiler::DeviceKind;
    let quad = Topology::builder("quad")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .build()
        .unwrap();
    let pl = p(&quad, vec![("T0", 0..2), ("T1", 2..4), ("T2", 4..6), ("T3", 6..8)]);
    assert!(pl.validate(&quad, 8).is_ok());
    assert_eq!(pl.describe(&quad), "T0[0..2] → T1[2..4] → T2[4..6] → T3[6..8]");
    // the same placement is meaningless against the (smaller) paper graph
    // only if an id is out of range — id reuse across topologies is the
    // caller's responsibility, the bounds check is ours
    let oob = Placement { stages: vec![Stage { resource: ResourceId(9), range: 0..8 }] };
    assert!(oob.validate(&quad, 8).is_err());
}
