//! Fault-injection acceptance for the session plane: every way a
//! camera or an inter-site hop can misbehave must end in a *verdict*,
//! never a wedge.
//!
//! - **Mid-frame disconnect** — a camera dies halfway through a frame:
//!   the session closes `PeerDisconnect`, unclean, nothing delivered.
//! - **Slow-loris** — a header arrives, then the drip stops: the
//!   evidence-based idle scan evicts the session with `IdleTimeout`
//!   (healthy-but-quiet sessions are never touched — the reactor only
//!   evicts on a stall *symptom*: a half-received frame).
//! - **Stalled reader** — a camera sends frames but never reads its
//!   acks: kernel buffers fill (shrunk via `setsockopt` so the test is
//!   fast), the egress queue wedges, and the session is evicted
//!   `WriteStalled` (Linux-only: buffer inheritance from the listener).
//! - **Flaky hop** — an uplink's connect attempts are refused until its
//!   circuit breaker opens; when the hop comes back, the half-open
//!   probe reconnects, and frames queued while it was down flush in
//!   order.
//! - **Graceful degradation** — a live [`Server`] with a dead uplink
//!   surfaces [`ServerEvent::Degraded`] and routes the failure through
//!   the ordinary §V hot-swap path ([`SwapCompleted`] on record).

use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::Receiver;
use std::thread;
use std::time::{Duration, Instant};

use serdab::coordinator::{Server, ServerConfig, ServerEvent, SessionPolicy, SyntheticBuilder};
use serdab::net::reactor::{self, ReactorConfig, ReactorEvent, ReactorHandle, UplinkPolicy};
use serdab::net::{read_frame, CircuitState, CloseReason, FrameType};
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::topology::{LinkParams, Topology};

#[allow(clippy::type_complexity)]
fn spawn_reactor(
    cfg: ReactorConfig,
) -> (
    std::net::SocketAddr,
    ReactorHandle,
    Receiver<ReactorEvent>,
    std::thread::JoinHandle<serdab::net::ReactorStats>,
) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (h, rx, j) = reactor::spawn(listener, cfg).unwrap();
    (addr, h, rx, j)
}

/// Drain events until a `Closed` arrives; panics on timeout.
fn wait_closed(rx: &Receiver<ReactorEvent>, timeout: Duration) -> (CloseReason, u64, u64, bool) {
    let deadline = Instant::now() + timeout;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(!left.is_zero(), "no Closed event within {timeout:?}");
        match rx.recv_timeout(left) {
            Ok(ReactorEvent::Closed { reason, frames_in, acked, clean, .. }) => {
                return (reason, frames_in, acked, clean)
            }
            Ok(_) => continue,
            Err(e) => panic!("event feed closed: {e}"),
        }
    }
}

/// Drain events until the uplink breaker reaches `want`; returns the
/// transition detail.
fn wait_uplink(rx: &Receiver<ReactorEvent>, want: CircuitState, timeout: Duration) -> String {
    let deadline = Instant::now() + timeout;
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(!left.is_zero(), "no UplinkState({want:?}) within {timeout:?}");
        match rx.recv_timeout(left) {
            Ok(ReactorEvent::UplinkState { state, detail, .. }) if state == want => return detail,
            Ok(_) => continue,
            Err(e) => panic!("event feed closed: {e}"),
        }
    }
}

/// A camera that dies halfway through a frame: header promising 64
/// payload bytes, ten delivered, then the socket drops.
#[test]
fn mid_frame_disconnect_closes_unclean() {
    let (addr, h, rx, j) = spawn_reactor(ReactorConfig::default());
    let mut client = TcpStream::connect(addr).unwrap();
    let mut partial = Vec::new();
    partial.extend_from_slice(&64u32.to_be_bytes());
    partial.push(FrameType::Data as u8);
    partial.extend_from_slice(&[0xAB; 10]);
    client.write_all(&partial).unwrap();
    drop(client);

    let (reason, frames_in, _, clean) = wait_closed(&rx, Duration::from_secs(5));
    assert_eq!(reason, CloseReason::PeerDisconnect);
    assert!(!clean, "a mid-frame cut can never count as a clean detach");
    assert_eq!(frames_in, 0, "the truncated frame must not be delivered");

    h.shutdown();
    let stats = j.join().unwrap();
    assert_eq!(stats.peer_disconnects, 1);
    assert_eq!(stats.frames_in, 0);
}

/// A slow-loris that stalls mid-frame is evicted once the idle deadline
/// passes — with the socket still open (no disconnect to hide behind).
#[test]
fn slow_loris_is_evicted_with_idle_timeout() {
    let cfg =
        ReactorConfig { idle_timeout: Duration::from_millis(200), ..ReactorConfig::default() };
    let (addr, h, rx, j) = spawn_reactor(cfg);
    let mut client = TcpStream::connect(addr).unwrap();

    // a legitimate header (1024-byte frame coming)...
    let mut head = Vec::new();
    head.extend_from_slice(&1024u32.to_be_bytes());
    head.push(FrameType::Data as u8);
    client.write_all(&head).unwrap();
    // ...one dripped byte, then silence
    thread::sleep(Duration::from_millis(100));
    client.write_all(&[0x01]).unwrap();

    let (reason, frames_in, _, clean) = wait_closed(&rx, Duration::from_secs(5));
    assert_eq!(reason, CloseReason::IdleTimeout, "half-received frame + silence = slow-loris");
    assert!(!clean);
    assert_eq!(frames_in, 0);
    drop(client); // held open until the verdict so eviction is the only out

    h.shutdown();
    let stats = j.join().unwrap();
    assert_eq!(stats.evictions, 1);
}

/// A camera that sends frames but never reads its acks: with kernel
/// buffers shrunk to their minima (send side inherited from the
/// listener, receive side clamped on the client) the ack backlog
/// becomes unflushable and the session is evicted `WriteStalled`.
#[cfg(target_os = "linux")]
#[test]
fn stalled_reader_is_evicted_write_stalled() {
    use std::os::unix::io::AsRawFd;

    fn shrink(fd: i32, opt: libc::c_int) {
        let bytes: libc::c_int = 1; // the kernel clamps to its floor
        let rc = unsafe {
            libc::setsockopt(
                fd,
                libc::SOL_SOCKET,
                opt,
                &bytes as *const libc::c_int as *const libc::c_void,
                std::mem::size_of::<libc::c_int>() as libc::socklen_t,
            )
        };
        assert_eq!(rc, 0, "setsockopt failed");
    }

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    shrink(listener.as_raw_fd(), libc::SO_SNDBUF); // accepted sockets inherit
    let addr = listener.local_addr().unwrap();
    let cfg = ReactorConfig {
        idle_timeout: Duration::from_millis(300),
        max_inflight: 64,
        ..ReactorConfig::default()
    };
    let (h, rx, j) = reactor::spawn(listener, cfg).unwrap();

    // completer: every delivered frame immediately earns an ack
    let h2 = h.clone();
    let (closed_tx, closed_rx) = std::sync::mpsc::channel();
    let pump = thread::spawn(move || {
        while let Ok(ev) = rx.recv() {
            match ev {
                ReactorEvent::Frame { conn, .. } => h2.complete(conn),
                ReactorEvent::Closed { reason, clean, .. } => {
                    let _ = closed_tx.send((reason, clean));
                }
                _ => {}
            }
        }
    });

    let mut client = TcpStream::connect(addr).unwrap();
    shrink(client.as_raw_fd(), libc::SO_RCVBUF); // tiny ack window
    // 4000 empty frames = ~20 KB of acks against ~7 KB of kernel buffer
    let mut burst = Vec::new();
    for _ in 0..4000 {
        burst.extend_from_slice(&0u32.to_be_bytes());
        burst.push(FrameType::Data as u8);
    }
    client.write_all(&burst).unwrap();
    // never read a single ack; the socket stays open

    let (reason, clean) = closed_rx
        .recv_timeout(Duration::from_secs(15))
        .expect("stalled reader never evicted");
    assert_eq!(reason, CloseReason::WriteStalled, "unflushable egress must be the verdict");
    assert!(!clean);
    drop(client);

    h.shutdown();
    let stats = j.join().unwrap();
    pump.join().unwrap();
    assert_eq!(stats.evictions, 1);
    assert!(stats.frames_in > 0, "frames were delivered before the stall");
}

/// A flaky inter-site hop: refused connects trip the breaker (fast-fail
/// instead of hammering), the hop's return is discovered by the
/// half-open probe, and frames queued while it was down flush in order.
#[test]
fn uplink_breaker_trips_then_half_open_recovers() {
    let (_addr, h, rx, j) = spawn_reactor(ReactorConfig::default());

    // reserve a port for the hop, then kill it (connects now refused)
    let hop = TcpListener::bind("127.0.0.1:0").unwrap();
    let hop_addr = hop.local_addr().unwrap();
    drop(hop);

    h.add_uplink(
        0,
        hop_addr.to_string(),
        UplinkPolicy {
            connect_timeout: Duration::from_millis(100),
            backoff_base: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(50),
            breaker_threshold: 2,
            breaker_cooldown: Duration::from_millis(200),
            seed: 5,
            queue_cap: 16,
        },
    );
    // traffic offered while the hop is down queues (bounded) instead of
    // being lost or wedging the reactor
    for i in 0..3u8 {
        h.uplink_send(0, vec![i]);
    }

    let detail = wait_uplink(&rx, CircuitState::Open, Duration::from_secs(5));
    assert!(detail.contains("breaker tripped"), "unexpected trip detail: {detail}");

    // the hop comes back on the same port; the half-open probe finds it
    let hop = TcpListener::bind(hop_addr).unwrap();
    let detail = wait_uplink(&rx, CircuitState::Closed, Duration::from_secs(5));
    assert_eq!(detail, "half-open probe succeeded");

    // everything queued during the outage arrives, in order
    let (mut sock, _) = hop.accept().unwrap();
    sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    for want in 0..3u8 {
        let (ty, payload) = read_frame(&mut sock).unwrap();
        assert_eq!(ty, FrameType::Data);
        assert_eq!(payload, vec![want], "queued frames must flush in order");
    }

    h.shutdown();
    let stats = j.join().unwrap();
    assert!(stats.uplink_trips >= 1, "the trip must be counted: {stats:?}");
    assert!(stats.uplink_connects >= 1, "the recovery must be counted: {stats:?}");
    assert_eq!(stats.uplink_frames, 3);
    assert_eq!(stats.uplink_dropped, 0, "the outage queue stayed under its cap");
}

/// Same placement-rich graph as `tests/server_session.rs`.
fn quad_topology() -> Topology {
    Topology::builder("quad-chaos")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap()
}

/// A live [`Server`] whose configured uplink is dead: the tripped
/// breaker surfaces as [`ServerEvent::Degraded`] and — with
/// `repartition_on_trip` — routes through the ordinary hot-swap path
/// instead of wedging on the dead hop.
#[test]
fn dead_uplink_degrades_server_and_triggers_repartition() {
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let mut server = Server::launch(
        profile,
        topo,
        Box::new(builder),
        ServerConfig { window_secs: 0.1, ..ServerConfig::default() },
    )
    .unwrap();
    let events = server.events().unwrap();

    let hop = TcpListener::bind("127.0.0.1:0").unwrap();
    let hop_addr = hop.local_addr().unwrap();
    drop(hop);

    server
        .serve_sockets(
            TcpListener::bind("127.0.0.1:0").unwrap(),
            SessionPolicy {
                uplinks: vec![hop_addr.to_string()],
                uplink_policy: UplinkPolicy {
                    connect_timeout: Duration::from_millis(100),
                    backoff_base: Duration::from_millis(10),
                    backoff_cap: Duration::from_millis(50),
                    breaker_threshold: 2,
                    breaker_cooldown: Duration::from_millis(200),
                    seed: 5,
                    queue_cap: 16,
                },
                repartition_on_trip: true,
                ..SessionPolicy::default()
            },
        )
        .unwrap();

    let deadline = Instant::now() + Duration::from_secs(15);
    let (mut degraded, mut swapped) = (false, false);
    let mut seen = Vec::new();
    while !(degraded && swapped) {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(
            !left.is_zero(),
            "no degrade→swap within 15s (degraded={degraded}, swapped={swapped}); events: {seen:?}"
        );
        match events.recv_timeout(left) {
            Ok(ServerEvent::Degraded { reason, .. }) => {
                assert!(reason.contains("circuit opened"), "unexpected degrade reason: {reason}");
                degraded = true;
            }
            Ok(ServerEvent::SwapCompleted(_)) => swapped = true,
            Ok(ServerEvent::SwapFailed { error }) => panic!("degraded repartition failed: {error}"),
            Ok(ev) => seen.push(ev),
            Err(e) => panic!("event feed closed: {e}"),
        }
    }

    let report = server.shutdown().unwrap();
    assert!(!report.swaps.is_empty(), "the degradation swap must be on record");
    assert_eq!(report.frames_dropped, 0, "degradation must not drop frames");
    let stats = report.session_stats.expect("socket plane ran");
    assert!(stats.uplink_trips >= 1, "the breaker trip must be counted: {stats:?}");
}
