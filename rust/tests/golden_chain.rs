//! Integration: the Rust runtime must reproduce, block by block, the
//! golden activations the JAX reference produced at build time — proving
//! the AOT interchange (params + tensor encoding + block semantics) is
//! faithful end-to-end. This is the cross-language numerical contract,
//! exercised through whatever backend `SERDAB_BACKEND` selects (the
//! pure-Rust reference backend by default).

use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::runtime::{default_backend, ChainExecutor, Tensor};

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

#[test]
fn squeezenet_chain_matches_goldens() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let backend = default_backend().unwrap();
    let chain = ChainExecutor::load(backend.as_ref(), &man, "squeezenet").unwrap();
    let info = man.model("squeezenet").unwrap();

    let mut act = Tensor::from_bin_file(
        &man.path(&info.golden_input),
        man.input_shape.clone(),
    )
    .unwrap();
    for (i, b) in chain.blocks.iter().enumerate() {
        act = b.run(&act).unwrap();
        let golden =
            Tensor::from_bin_file(&man.path(&info.blocks[i].golden), act.shape.clone()).unwrap();
        let diff = act.max_abs_diff(&golden);
        assert!(diff < 1e-3, "block {i} ({}) diff {diff}", b.name);
        // continue the chain from the golden to avoid error accumulation
        act = golden;
    }
}

#[test]
fn every_model_final_output_matches_golden() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let backend = default_backend().unwrap();
    for name in serdab::model::MODEL_NAMES {
        let info = man.model(name).unwrap();
        let chain = ChainExecutor::load(backend.as_ref(), &man, name).unwrap();
        let input =
            Tensor::from_bin_file(&man.path(&info.golden_input), man.input_shape.clone()).unwrap();
        let out = chain.run(&input).unwrap();
        let last = info.blocks.last().unwrap();
        let golden = Tensor::from_bin_file(&man.path(&last.golden), last.out_shape.clone()).unwrap();
        let diff = out.max_abs_diff(&golden);
        assert!(diff < 2e-2, "{name}: final diff {diff}");
    }
}

#[test]
fn range_split_equals_full_chain() {
    // executing 0..c then c..M across two "enclaves" must equal 0..M —
    // the numerical core of the partitioning claim
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let backend = default_backend().unwrap();
    let name = "alexnet";
    let info = man.model(name).unwrap();
    let m = info.m();
    let cut = m / 2;

    let full = ChainExecutor::load(backend.as_ref(), &man, name).unwrap();
    let first = ChainExecutor::load_range(backend.as_ref(), &man, name, 0..cut).unwrap();
    let second = ChainExecutor::load_range(backend.as_ref(), &man, name, cut..m).unwrap();

    let input =
        Tensor::from_bin_file(&man.path(&info.golden_input), man.input_shape.clone()).unwrap();
    let whole = full.run(&input).unwrap();
    let mid = first.run(&input).unwrap();
    let split = second.run(&mid).unwrap();
    let diff = whole.max_abs_diff(&split);
    assert!(diff < 1e-5, "split diff {diff}");
}
