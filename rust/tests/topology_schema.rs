//! The shipped topology JSON files and the schema's save/load round-trip:
//! `examples/topologies/paper.json` must load to exactly
//! `Topology::paper_testbed()` (the file is the data form of the seed
//! graph), and every shipped example must be a valid, solvable topology.

use std::path::PathBuf;

use serdab::profiler::DeviceKind;
use serdab::topology::{gen, LinkParams, Topology};
use serdab::util::json::Json;

fn topologies_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/topologies")
}

#[test]
fn shipped_paper_json_is_the_paper_testbed() {
    let loaded = Topology::load(topologies_dir().join("paper.json")).unwrap();
    assert_eq!(loaded, Topology::paper_testbed());
}

#[test]
fn shipped_edge4_is_a_four_tee_cluster() {
    let t = Topology::load(topologies_dir().join("edge4.json")).unwrap();
    assert_eq!(t.tees().len(), 4);
    assert!(t.len() >= 6);
    assert_eq!(t.hosts(), 4);
    // camera attaches by resource name ("TEE-A" on host 0)
    assert_eq!(t.camera_host, 0);
    assert_eq!(t.name_of(t.entry()), "TEE-A");
    // explicit links resolve by resource name, others use the default
    assert!((t.link(0, 1).bandwidth_bps - 100e6).abs() < 1e-6);
    assert!((t.link(0, 3).bandwidth_bps - 50e6).abs() < 1e-6);
}

#[test]
fn shipped_gpu_cloud_has_speed_and_epc_overrides() {
    let t = Topology::load(topologies_dir().join("gpu_cloud.json")).unwrap();
    let gpu = t.require("CLOUD-GPU").unwrap();
    assert_eq!(t.kind_of(gpu), DeviceKind::Gpu);
    assert!((t.resource(gpu).speed - 4.0).abs() < 1e-12);
    let tee = t.require("EDGE-TEE").unwrap();
    let epc = t.resource(tee).epc.as_ref().expect("per-enclave EPC override");
    assert_eq!(epc.epc_bytes, 97_517_568);
}

#[test]
fn save_then_load_round_trips_every_shipped_example() {
    let dir = std::env::temp_dir().join(format!("serdab-topo-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for file in ["paper.json", "edge4.json", "gpu_cloud.json"] {
        let t = Topology::load(topologies_dir().join(file)).unwrap();
        let out = dir.join(file);
        t.save(&out).unwrap();
        let back = Topology::load(&out).unwrap();
        assert_eq!(t, back, "{file} changed across save/load");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn load_reports_file_context_on_errors() {
    let missing = topologies_dir().join("nope.json");
    let e = Topology::load(&missing).unwrap_err();
    assert!(format!("{e:#}").contains("nope.json"), "{e:#}");

    // a malformed file errors with its path and the json position
    let dir = std::env::temp_dir().join(format!("serdab-topo-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{ not json").unwrap();
    let e = Topology::load(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("bad.json"), "{e:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn custom_link_params_survive_round_trip() {
    let mut t = Topology::paper_testbed();
    t.set_link(0, 1, LinkParams { bandwidth_bps: 2.5e6, rtt_secs: 0.042 });
    t.crypto_bytes_per_sec = 123e6;
    let json = t.to_json().to_string();
    let back = Topology::from_json(&Json::parse(&json).unwrap()).unwrap();
    assert_eq!(t, back);
}

/// Duplicate names are rejected at load with both colliding entries
/// labeled, not just the name.
#[test]
fn load_labels_both_entries_of_a_duplicate_resource_name() {
    let doc = r#"{
        "name": "dup",
        "resources": [
            {"name": "TEE", "kind": "tee", "host": 0},
            {"name": "CPU", "kind": "cpu", "host": 0},
            {"name": "TEE", "kind": "gpu", "host": 0}
        ]
    }"#;
    let e = Topology::from_json(&Json::parse(doc).unwrap()).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("resource [2]: duplicate resource name 'TEE'"), "{msg}");
    assert!(msg.contains("already declared by resource [0]"), "{msg}");
}

/// With `"default_link": "none"` a resource whose host has no declared
/// path to the camera is rejected — and the error names it.
#[test]
fn load_rejects_unreachable_resources_and_names_them() {
    let doc = r#"{
        "name": "strand",
        "default_link": "none",
        "resources": [
            {"name": "T0", "kind": "tee", "host": 0},
            {"name": "T1", "kind": "tee", "host": 1},
            {"name": "FAR", "kind": "cpu", "host": 2}
        ],
        "links": [
            {"a": 0, "b": 1, "bandwidth_bps": 100000000, "rtt_secs": 0.005}
        ]
    }"#;
    let e = Topology::from_json(&Json::parse(doc).unwrap()).unwrap_err();
    let msg = format!("{e:#}");
    assert!(msg.contains("unreachable from camera host 0"), "{msg}");
    assert!(msg.contains("'FAR'"), "{msg}");
}

/// Under `"default_link": "none"` non-adjacent host pairs are routed
/// over the declared graph: bottleneck bandwidth, summed rtt.
#[test]
fn load_routes_multi_hop_host_pairs_over_declared_links() {
    let doc = r#"{
        "name": "chain",
        "default_link": "none",
        "resources": [
            {"name": "T0", "kind": "tee", "host": 0},
            {"name": "T1", "kind": "tee", "host": 1},
            {"name": "T2", "kind": "tee", "host": 2}
        ],
        "links": [
            {"a": 0, "b": 1, "bandwidth_bps": 100000000, "rtt_secs": 0.005},
            {"a": 1, "b": 2, "bandwidth_bps": 50000000, "rtt_secs": 0.002}
        ]
    }"#;
    let t = Topology::from_json(&Json::parse(doc).unwrap()).unwrap();
    // declared links are untouched
    assert!((t.link(0, 1).bandwidth_bps - 100e6).abs() < 1e-6);
    assert!((t.link(1, 2).rtt_secs - 0.002).abs() < 1e-12);
    // the 0↔2 pair is materialized from the 0-1-2 path
    let routed = t.link(0, 2);
    assert!((routed.bandwidth_bps - 50e6).abs() < 1e-6, "bottleneck bandwidth");
    assert!((routed.rtt_secs - 0.007).abs() < 1e-12, "summed rtt");
}

/// Same (kind, resources, seed) spec ⇒ identical fleet; different seeds
/// actually vary it.
#[test]
fn fleet_generator_is_deterministic_per_spec() {
    for kind in [gen::GenKind::Tree, gen::GenKind::Random] {
        let spec = gen::GenSpec { kind, resources: 64, seed: 9 };
        assert_eq!(gen::generate(&spec).unwrap(), gen::generate(&spec).unwrap());
    }
    let s1 = gen::GenSpec { kind: gen::GenKind::Tree, resources: 64, seed: 1 };
    let s2 = gen::GenSpec { kind: gen::GenKind::Tree, resources: 64, seed: 2 };
    assert_ne!(gen::generate(&s1).unwrap(), gen::generate(&s2).unwrap());
}

/// The checked-in generated fleets are exactly what `topo gen` produces
/// for their specs — loading and regenerating agree — and they carry the
/// scale the fleet-solver benchmarks claim.
#[test]
fn shipped_generated_fleets_match_their_generator_specs() {
    let cases = [
        ("tree64.json", gen::GenKind::Tree, 64, 64, 31),
        ("tree256.json", gen::GenKind::Tree, 256, 256, 124),
        ("rand1024.json", gen::GenKind::Random, 1024, 1024, 256),
    ];
    for (file, kind, resources, seed, hosts) in cases {
        let loaded = Topology::load(topologies_dir().join(file)).unwrap();
        let spec = gen::GenSpec { kind, resources, seed };
        let generated = gen::generate(&spec).unwrap();
        assert_eq!(loaded, generated, "{file} drifted from its generator spec");
        assert_eq!(loaded.len(), resources, "{file}: resource count");
        assert_eq!(loaded.hosts(), hosts, "{file}: host count");
        assert!(!loaded.tees().is_empty(), "{file}: no enclave");
        assert_eq!(loaded.camera_host, 0, "{file}: camera host");
    }
}
