//! The shipped topology JSON files and the schema's save/load round-trip:
//! `examples/topologies/paper.json` must load to exactly
//! `Topology::paper_testbed()` (the file is the data form of the seed
//! graph), and every shipped example must be a valid, solvable topology.

use std::path::PathBuf;

use serdab::profiler::DeviceKind;
use serdab::topology::{LinkParams, Topology};

fn topologies_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/topologies")
}

#[test]
fn shipped_paper_json_is_the_paper_testbed() {
    let loaded = Topology::load(topologies_dir().join("paper.json")).unwrap();
    assert_eq!(loaded, Topology::paper_testbed());
}

#[test]
fn shipped_edge4_is_a_four_tee_cluster() {
    let t = Topology::load(topologies_dir().join("edge4.json")).unwrap();
    assert_eq!(t.tees().len(), 4);
    assert!(t.len() >= 6);
    assert_eq!(t.hosts(), 4);
    // camera attaches by resource name ("TEE-A" on host 0)
    assert_eq!(t.camera_host, 0);
    assert_eq!(t.name_of(t.entry()), "TEE-A");
    // explicit links resolve by resource name, others use the default
    assert!((t.link(0, 1).bandwidth_bps - 100e6).abs() < 1e-6);
    assert!((t.link(0, 3).bandwidth_bps - 50e6).abs() < 1e-6);
}

#[test]
fn shipped_gpu_cloud_has_speed_and_epc_overrides() {
    let t = Topology::load(topologies_dir().join("gpu_cloud.json")).unwrap();
    let gpu = t.require("CLOUD-GPU").unwrap();
    assert_eq!(t.kind_of(gpu), DeviceKind::Gpu);
    assert!((t.resource(gpu).speed - 4.0).abs() < 1e-12);
    let tee = t.require("EDGE-TEE").unwrap();
    let epc = t.resource(tee).epc.as_ref().expect("per-enclave EPC override");
    assert_eq!(epc.epc_bytes, 97_517_568);
}

#[test]
fn save_then_load_round_trips_every_shipped_example() {
    let dir = std::env::temp_dir().join(format!("serdab-topo-rt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for file in ["paper.json", "edge4.json", "gpu_cloud.json"] {
        let t = Topology::load(topologies_dir().join(file)).unwrap();
        let out = dir.join(file);
        t.save(&out).unwrap();
        let back = Topology::load(&out).unwrap();
        assert_eq!(t, back, "{file} changed across save/load");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn load_reports_file_context_on_errors() {
    let missing = topologies_dir().join("nope.json");
    let e = Topology::load(&missing).unwrap_err();
    assert!(format!("{e:#}").contains("nope.json"), "{e:#}");

    // a malformed file errors with its path and the json position
    let dir = std::env::temp_dir().join(format!("serdab-topo-bad-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{ not json").unwrap();
    let e = Topology::load(&bad).unwrap_err();
    assert!(format!("{e:#}").contains("bad.json"), "{e:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn custom_link_params_survive_round_trip() {
    let mut t = Topology::paper_testbed();
    t.set_link(0, 1, LinkParams { bandwidth_bps: 2.5e6, rtt_secs: 0.042 });
    t.crypto_bytes_per_sec = 123e6;
    let json = t.to_json().to_string();
    let back = Topology::from_json(&serdab::util::json::Json::parse(&json).unwrap()).unwrap();
    assert_eq!(t, back);
}
