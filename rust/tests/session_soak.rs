//! Soak/churn acceptance for the async session plane: ONE reactor
//! thread multiplexes a thousand camera sessions through attach/detach
//! churn with zero frame loss on clean detach and bounded memory.
//!
//! Two layers are soaked back to back:
//!
//! - **Reactor churn** — two equal waves of [`SocketSwarm`] clients
//!   (scripted 10% abrupt disconnects) against a bare reactor with an
//!   immediate completer. Clean clients must see every frame acked;
//!   the reactor's close accounting must match the swarm's outcome
//!   table exactly; the second wave must not allocate materially more
//!   than the first (steady state — the counting allocator below is
//!   the same pattern as `tests/alloc_steady_state.rs`).
//! - **Server integration** — the same swarm against a live
//!   [`Server`] with `serve_sockets`: socket sessions become streams,
//!   their frames drain through the synthetic pipeline, and the final
//!   [`ServerReport`] proves `completed == fed` per stream with
//!   `frames_dropped == 0`.
//!
//! Platform probes assert the structural claims: exactly one
//! `serdab-reactor` thread exists while serving (`/proc/self/task`),
//! and the process-wide fd count is unchanged once everything is shut
//! down (`/proc/self/fd` — leaked sockets/epoll fds fail here). The
//! run writes `SOAK_session.json` for the CI artifact.
//!
//! The CI profile (1000 reactor sessions + 30 server sessions) runs in
//! the default suite; the 10× profile is `#[ignore]`d and additionally
//! gated on `SERDAB_SOAK=1`. Both profiles serialize on one lock so the
//! allocation counters never see a concurrent sibling.

use std::alloc::{GlobalAlloc, Layout, System};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::thread;
use std::time::{Duration, Instant};

use serdab::coordinator::{Server, ServerConfig, ServerEvent, SessionPolicy, SyntheticBuilder};
use serdab::net::reactor::{self, ReactorConfig, ReactorEvent, ReactorStats};
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::runtime::{SocketSwarm, SwarmConfig, SwarmReport};
use serdab::topology::{LinkParams, Topology};

// ---------------------------------------------------------------------------
// counting allocator (global): allocation-rate probe for the soak waves
// ---------------------------------------------------------------------------

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// Counting is monotone and Relaxed: we only compare totals at quiescent
// points, never order against other memory operations.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// Serializes the CI and 10× profiles (`--include-ignored` would
/// otherwise run them in parallel and pollute the allocation counter).
static SOAK_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// platform probes (Linux /proc; None elsewhere → assertion skipped)
// ---------------------------------------------------------------------------

/// How many live threads are named `serdab-reactor`.
fn reactor_threads() -> Option<usize> {
    let dir = std::fs::read_dir("/proc/self/task").ok()?;
    let mut n = 0;
    for entry in dir.flatten() {
        if let Ok(comm) = std::fs::read_to_string(entry.path().join("comm")) {
            if comm.trim_end() == "serdab-reactor" {
                n += 1;
            }
        }
    }
    Some(n)
}

/// Process-wide open-fd count (includes the probe's own dirfd — a
/// constant, so before/after equality still detects leaks).
fn open_fds() -> Option<usize> {
    Some(std::fs::read_dir("/proc/self/fd").ok()?.count())
}

// ---------------------------------------------------------------------------
// soak profiles
// ---------------------------------------------------------------------------

struct SoakProfile {
    label: &'static str,
    /// Reactor-churn clients per wave (two waves run).
    wave_clients: usize,
    /// Frames each churn client sends before its EOS.
    frames: u64,
    /// Live-session ceiling the swarm holds the reactor at.
    concurrent: usize,
    /// Attach pacing between client launches (seconds).
    attach_interval: f64,
    /// Server-integration clients.
    server_clients: usize,
    /// Frames per server-integration client.
    server_frames: u64,
    /// Live-session ceiling for the server phase.
    server_concurrent: usize,
    /// Swarm give-up deadline, seconds.
    timeout_secs: f64,
}

impl SoakProfile {
    /// CI profile: 2×500 reactor sessions + 30 pipeline-backed sessions.
    fn short() -> SoakProfile {
        SoakProfile {
            label: "short",
            wave_clients: 500,
            frames: 4,
            concurrent: 120,
            attach_interval: 0.002,
            server_clients: 30,
            server_frames: 5,
            server_concurrent: 12,
            timeout_secs: 90.0,
        }
    }

    /// 10× profile for `SERDAB_SOAK=1 cargo test -- --ignored`.
    fn full() -> SoakProfile {
        SoakProfile {
            label: "full-10x",
            wave_clients: 5000,
            frames: 4,
            concurrent: 200,
            attach_interval: 0.002,
            server_clients: 120,
            server_frames: 5,
            server_concurrent: 24,
            timeout_secs: 480.0,
        }
    }
}

/// Same placement-rich graph as `tests/server_session.rs`.
fn quad_topology() -> Topology {
    Topology::builder("quad-soak")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap()
}

/// Per-wave outcome digest.
struct WaveDigest {
    clean: usize,
    abrupt: usize,
    clean_fed: u64,
}

/// Every non-abrupt client must have detached cleanly with all frames
/// acked — the "zero frame loss on clean detach" claim.
fn assert_no_loss(rep: &SwarmReport, frames: u64) -> WaveDigest {
    let mut digest = WaveDigest { clean: 0, abrupt: 0, clean_fed: 0 };
    for o in &rep.outcomes {
        if o.abrupt {
            digest.abrupt += 1;
            assert!(!o.clean, "scripted abrupt client cannot be clean: {o:?}");
        } else {
            digest.clean += 1;
            digest.clean_fed += o.fed;
            assert!(o.clean, "well-behaved client failed its detach handshake: {o:?}");
            assert_eq!(o.fed, frames, "clean client under-fed: {o:?}");
            assert_eq!(o.acked, o.fed, "clean detach lost frames: {o:?}");
        }
    }
    digest
}

fn churn_wave(addr: SocketAddr, p: &SoakProfile, seed: u64) -> (SwarmReport, u64) {
    let a0 = allocs();
    let rep = SocketSwarm::new(SwarmConfig {
        clients: p.wave_clients,
        max_concurrent: p.concurrent,
        frames_per_client: p.frames,
        payload_bytes: 32,
        abrupt_fraction: 0.10,
        attach_interval_secs: p.attach_interval,
        seed,
        timeout_secs: p.timeout_secs,
        ..SwarmConfig::default()
    })
    .run(addr)
    .expect("churn wave");
    (rep, allocs() - a0)
}

// ---------------------------------------------------------------------------
// the soak itself
// ---------------------------------------------------------------------------

#[allow(clippy::too_many_lines)]
fn run_soak(p: &SoakProfile) {
    let t0 = Instant::now();
    let fds_before = open_fds();

    // ---- phase 1: reactor-level churn, immediate completer -------------
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let (handle, events, join) = reactor::spawn(listener, ReactorConfig::default()).unwrap();
    let h2 = handle.clone();
    let completer = thread::spawn(move || {
        while let Ok(ev) = events.recv() {
            if let ReactorEvent::Frame { conn, .. } = ev {
                h2.complete(conn);
            }
        }
    });

    if let Some(n) = reactor_threads() {
        assert_eq!(n, 1, "exactly one reactor thread must serve every session");
    }

    let (rep1, wave1_allocs) = churn_wave(addr, p, 11);
    let (rep2, wave2_allocs) = churn_wave(addr, p, 22);
    let d1 = assert_no_loss(&rep1, p.frames);
    let d2 = assert_no_loss(&rep2, p.frames);

    // bounded memory: a steady-state wave over the same session count
    // must not allocate materially more than the warm-up wave (an
    // unbounded per-session residue — a conn map that never shrinks, a
    // buffer that only grows — shows up as allocation-rate growth)
    assert!(
        wave2_allocs <= wave1_allocs + wave1_allocs / 2 + 20_000,
        "allocation rate grew across equal waves: wave1 {wave1_allocs}, wave2 {wave2_allocs}"
    );

    // let the last abrupt disconnects land before reading the counters
    thread::sleep(Duration::from_millis(300));
    handle.shutdown();
    let stats = join.join().unwrap();
    completer.join().unwrap();

    let sessions_total = 2 * p.wave_clients;
    let clean_total = d1.clean + d2.clean;
    let abrupt_total = d1.abrupt + d2.abrupt;
    let clean_fed = d1.clean_fed + d2.clean_fed;
    assert_eq!(stats.accepted as usize, sessions_total, "every client must be admitted");
    assert_eq!(stats.rejected, 0, "churn stayed under the admission cap");
    assert_eq!(stats.clean_closes as usize, clean_total, "clean-close ledger disagrees: {stats:?}");
    assert_eq!(
        stats.peer_disconnects as usize, abrupt_total,
        "abrupt disconnects must be accounted as PeerDisconnect: {stats:?}"
    );
    assert_eq!(stats.evictions, 0, "no healthy session may be evicted: {stats:?}");
    // clean clients' frames are all decoded and acked; abrupt clients may
    // lose tail bytes to the RST, so those only bound from below
    assert!(stats.frames_in >= clean_fed, "decoded {} < clean fed {clean_fed}", stats.frames_in);
    assert!(stats.acks_out >= clean_fed, "acked {} < clean fed {clean_fed}", stats.acks_out);

    // ---- phase 2: the same swarm against a live Server ------------------
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let mut server =
        Server::launch(profile, topo, Box::new(builder), ServerConfig::default()).unwrap();
    let sev = server.events().unwrap();
    let collector = thread::spawn(move || {
        let mut closed = Vec::new();
        while let Ok(ev) = sev.recv() {
            if let ServerEvent::SessionClosed { clean, fed, acked, .. } = ev {
                closed.push((clean, fed, acked));
            }
        }
        closed
    });
    let saddr = server
        .serve_sockets(TcpListener::bind("127.0.0.1:0").unwrap(), SessionPolicy::default())
        .unwrap();
    if let Some(n) = reactor_threads() {
        assert_eq!(n, 1, "the server's socket plane must also be a single reactor thread");
    }

    let srep = SocketSwarm::new(SwarmConfig {
        clients: p.server_clients,
        max_concurrent: p.server_concurrent,
        frames_per_client: p.server_frames,
        payload_bytes: 64,
        abrupt_fraction: 0.10,
        attach_interval_secs: 0.005,
        seed: 33,
        timeout_secs: p.timeout_secs,
        ..SwarmConfig::default()
    })
    .run(saddr)
    .expect("server swarm");
    let sd = assert_no_loss(&srep, p.server_frames);

    thread::sleep(Duration::from_millis(300));
    let report = server.shutdown().unwrap();
    let closed = collector.join().unwrap();

    assert_eq!(report.frames_dropped, 0, "socket sessions must never drop frames");
    assert_eq!(report.sink_errors, 0);
    let sstats = report.session_stats.as_ref().expect("socket plane ran");
    assert_eq!(sstats.clean_closes as usize, sd.clean, "server clean-close ledger: {sstats:?}");
    assert_eq!(sstats.evictions, 0, "no server session may be evicted: {sstats:?}");
    assert_eq!(
        closed.len(),
        srep.outcomes.len(),
        "every swarm session must surface a SessionClosed event"
    );
    for (clean, fed, acked) in &closed {
        if *clean {
            assert_eq!(acked, fed, "clean session closed with unacked frames");
        }
    }
    // the pipeline drained every frame the reactor delivered
    for s in &report.streams {
        assert_eq!(s.completed, s.fed, "stream {} lost frames: {s:?}", s.label);
    }
    let total_fed: u64 = report.streams.iter().map(|s| s.fed).sum();
    assert_eq!(report.frames, total_fed, "all delivered frames must drain to the sink");

    // ---- epilogue: fd balance + report artifact -------------------------
    let fds_after = open_fds();
    if let (Some(before), Some(after)) = (fds_before, fds_after) {
        assert_eq!(after, before, "file descriptors leaked across the soak");
    }
    let server_row = report_row(&srep, &closed, total_fed);
    write_report(
        p,
        t0.elapsed(),
        &stats,
        sessions_total,
        clean_total,
        abrupt_total,
        &server_row,
        wave1_allocs,
        wave2_allocs,
        fds_before,
        fds_after,
    );
}

/// Server-phase digest for the JSON artifact.
struct ServerRow {
    sessions: usize,
    clean: usize,
    frames: u64,
}

fn report_row(srep: &SwarmReport, closed: &[(bool, u64, u64)], frames: u64) -> ServerRow {
    ServerRow {
        sessions: srep.outcomes.len(),
        clean: closed.iter().filter(|(c, _, _)| *c).count(),
        frames,
    }
}

#[allow(clippy::too_many_arguments)]
fn write_report(
    p: &SoakProfile,
    elapsed: Duration,
    stats: &ReactorStats,
    sessions_total: usize,
    clean_total: usize,
    abrupt_total: usize,
    server: &ServerRow,
    wave1_allocs: u64,
    wave2_allocs: u64,
    fds_before: Option<usize>,
    fds_after: Option<usize>,
) {
    let fd = |v: Option<usize>| v.map_or_else(|| "null".into(), |n| n.to_string());
    let json = format!(
        "{{\n  \"profile\": \"{}\",\n  \"elapsed_secs\": {:.3},\n  \"reactor\": {{\n    \
         \"sessions\": {},\n    \"clean\": {},\n    \"abrupt\": {},\n    \"accepted\": {},\n    \
         \"clean_closes\": {},\n    \"peer_disconnects\": {},\n    \"evictions\": {},\n    \
         \"frames_in\": {},\n    \"acks_out\": {},\n    \"bytes_in\": {},\n    \"bytes_out\": {}\n  \
         }},\n  \"server\": {{\n    \"sessions\": {},\n    \"clean\": {},\n    \"frames\": {}\n  \
         }},\n  \"allocs_wave1\": {},\n  \"allocs_wave2\": {},\n  \"fds_before\": {},\n  \
         \"fds_after\": {}\n}}\n",
        p.label,
        elapsed.as_secs_f64(),
        sessions_total,
        clean_total,
        abrupt_total,
        stats.accepted,
        stats.clean_closes,
        stats.peer_disconnects,
        stats.evictions,
        stats.frames_in,
        stats.acks_out,
        stats.bytes_in,
        stats.bytes_out,
        server.sessions,
        server.clean,
        server.frames,
        wave1_allocs,
        wave2_allocs,
        fd(fds_before),
        fd(fds_after),
    );
    std::fs::write("SOAK_session.json", json).expect("writing SOAK_session.json");
}

#[test]
fn session_plane_soaks_one_thousand_streams() {
    let _guard = SOAK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    run_soak(&SoakProfile::short());
}

#[test]
#[ignore = "10x soak; run with SERDAB_SOAK=1 cargo test -- --ignored"]
fn session_plane_soaks_ten_thousand_streams() {
    if !matches!(std::env::var("SERDAB_SOAK").as_deref(), Ok("1")) {
        eprintln!("skipping 10x soak: set SERDAB_SOAK=1 to enable");
        return;
    }
    let _guard = SOAK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    run_soak(&SoakProfile::full());
}
