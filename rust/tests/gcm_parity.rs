//! Dispatched-vs-scalar AES-GCM parity: the AES-NI + CLMUL sealed-record
//! path must be **bit-identical** to the portable scalar path on every
//! input — NIST vectors, randomized records over awkward lengths (empty,
//! sub-block, partial tail blocks, multi-KiB), and cross-backend
//! open (a record sealed by either backend opens under the other).
//!
//! CI runs this suite across the `SERDAB_THREADS` matrix and once more
//! with `SERDAB_NO_AESNI=1`; in the forced-scalar run the dispatched
//! context *is* the scalar context, so the suite degenerates to
//! scalar-vs-scalar self-consistency (still a valid NIST check).

use serdab::crypto::gcm::{aesni_available, AesGcm};
use serdab::util::rng::Rng;

fn unhex(s: &str) -> Vec<u8> {
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).unwrap())
        .collect()
}

/// Seal under both backends, check ciphertext + tag against the expected
/// hex, and open each result under the *other* backend.
fn check_vector(key: &[u8; 16], nonce: &[u8; 12], aad: &[u8], pt: &[u8], ct: &str, tag: &str) {
    let fast = AesGcm::new(key);
    let slow = AesGcm::new_scalar(key);
    for (sealer, opener) in [(&fast, &slow), (&slow, &fast)] {
        let mut data = pt.to_vec();
        let t = sealer.seal(nonce, aad, &mut data);
        assert_eq!(data, unhex(ct), "ciphertext mismatch");
        assert_eq!(t.to_vec(), unhex(tag), "tag mismatch");
        opener.open(nonce, aad, &mut data, &t).expect("cross-backend open");
        assert_eq!(data, pt, "round trip lost the plaintext");
    }
}

#[test]
fn nist_vectors_on_both_paths() {
    // NIST GCM test case 1: key=0^128, nonce=0^96, empty pt/aad
    check_vector(&[0u8; 16], &[0u8; 12], &[], &[], "", "58e2fccefa7e3061367f1d57a4e7455a");
    // NIST GCM test case 2: pt = one zero block
    check_vector(
        &[0u8; 16],
        &[0u8; 12],
        &[],
        &[0u8; 16],
        "0388dace60b6a392f328c2b971b2fe78",
        "ab6e47d42cec13bdf53a67b21257bddf",
    );
    // NIST test case 4: 60-byte (partial-block) plaintext + AAD
    let key: [u8; 16] = unhex("feffe9928665731c6d6a8f9467308308").try_into().unwrap();
    let nonce: [u8; 12] = unhex("cafebabefacedbaddecaf888").try_into().unwrap();
    let aad = unhex("feedfacedeadbeeffeedfacedeadbeefabaddad2");
    let pt = unhex(
        "d9313225f88406e5a55909c5aff5269a86a7a9531534f7da2e4c303d8a318a72\
         1c3c0c95956809532fcf0e2449a6b525b16aedf5aa0de657ba637b39",
    );
    check_vector(
        &key,
        &nonce,
        &aad,
        &pt,
        "42831ec2217774244b7221b784d0d49ce3aa212f2c02a4e035c17e2329aca12e\
         21d514b25466931c7d8f6a5aac84aa051ba30b396a0aac973d58e091",
        "5bc94fbc3221a5db94fae95ae7121a47",
    );
}

#[test]
fn randomized_records_bitwise_identical() {
    let mut rng = Rng::new(0x9c39_71e5);
    // awkward lengths around block boundaries plus multi-KiB records
    let mut lens: Vec<usize> = vec![0, 1, 15, 16, 17, 31, 32, 33, 255, 256, 257];
    for _ in 0..8 {
        lens.push(rng.range(1, 128 << 10));
    }
    for (case, &len) in lens.iter().enumerate() {
        let mut key = [0u8; 16];
        key.iter_mut().for_each(|b| *b = rng.range(0, 256) as u8);
        let mut nonce = [0u8; 12];
        nonce.iter_mut().for_each(|b| *b = rng.range(0, 256) as u8);
        let aad: Vec<u8> = (0..rng.range(0, 48)).map(|_| rng.range(0, 256) as u8).collect();
        let pt: Vec<u8> = (0..len).map(|_| rng.range(0, 256) as u8).collect();

        let fast = AesGcm::new(&key);
        let slow = AesGcm::new_scalar(&key);
        let mut a = pt.clone();
        let mut b = pt.clone();
        let ta = fast.seal(&nonce, &aad, &mut a);
        let tb = slow.seal(&nonce, &aad, &mut b);
        assert_eq!(a, b, "case {case} (len {len}): ciphertext diverged");
        assert_eq!(ta, tb, "case {case} (len {len}): tag diverged");

        // cross-backend open, then a flipped bit must fail on both
        slow.open(&nonce, &aad, &mut a, &ta).expect("scalar opens dispatched record");
        assert_eq!(a, pt);
        fast.open(&nonce, &aad, &mut b, &tb).expect("dispatched opens scalar record");
        assert_eq!(b, pt);
        let mut bad = ta;
        bad[rng.range(0, 16)] ^= 1 << rng.range(0, 8);
        let mut c = pt.clone();
        fast.seal(&nonce, &aad, &mut c);
        assert!(fast.open(&nonce, &aad, &mut c.clone(), &bad).is_err());
        assert!(slow.open(&nonce, &aad, &mut c, &bad).is_err());
    }
}

#[test]
fn dispatch_matches_machine_capability() {
    // `accelerated()` must agree with the module-level probe at
    // construction time, and the pinned-scalar constructor never
    // accelerates — on any machine, under any env.
    let g = AesGcm::new(b"dispatch-probe-k");
    assert_eq!(g.accelerated(), aesni_available());
    assert!(!AesGcm::new_scalar(b"dispatch-probe-k").accelerated());
}
