//! Fleet-solver contract (DESIGN.md §18).
//!
//! The scalable solver must be invisible at paper scale — below the
//! exact-delegation threshold [`fleet::solve`] IS the exhaustive planner,
//! placement-for-placement, across every strategy and chunk size — and
//! bounded above it: on generated 64/256-resource fleets the beam search
//! must return valid, privacy-satisfying placements inside its node
//! budget, deterministically. Placement-cache hits must be
//! indistinguishable from the cold solves they stand in for, and the
//! incremental re-solve must never hand back a plan worse than the
//! standing placement it repairs.

use serdab::model::DELTA_RESOLUTION;
use serdab::placement::cost::CostModel;
use serdab::placement::fleet::{self, PlacementCache, SolveMode, SolverOpts};
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::Placement;
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::topology::{gen, LinkParams, Topology};

fn gen_topo(kind: gen::GenKind, n: usize, seed: u64) -> Topology {
    gen::generate(&gen::GenSpec { kind, resources: n, seed }).unwrap()
}

fn objective(cm: &CostModel<'_>, strategy: Strategy, p: &Placement, n: u64) -> f64 {
    let cost = cm.cost(p);
    match strategy {
        Strategy::NoPipelining => cost.single_secs,
        _ => cost.chunk_secs(n),
    }
}

/// Below the path-count threshold the fleet solver delegates to the
/// exhaustive planner — the paper-testbed golden placements are
/// byte-identical, for every strategy and chunk size.
#[test]
fn exact_mode_matches_exhaustive_plan_on_paper_testbed() {
    let profile = ModelProfile::millis_demo();
    let cm = CostModel::new(&profile, Topology::paper_testbed());
    let opts = SolverOpts::default();
    for s in Strategy::ALL {
        for n in [1u64, 10, 40, 1_000, 10_800] {
            let golden = plan(s, &cm, n);
            let fp = fleet::solve(s, &cm, n, &opts);
            let name = s.name();
            assert_eq!(fp.mode, SolveMode::Exact, "{name} n={n} escaped exact mode");
            assert_eq!(
                fp.plan.placement,
                golden.placement,
                "{name} n={n}: fleet solve diverged from the exhaustive plan"
            );
            assert_eq!(fp.nodes, golden.examined as u64);
            assert!(!fp.budget_exhausted);
        }
    }
}

/// A cache hit returns the bitwise-identical placement of the cold solve
/// it stands in for, and the counters attribute hits and misses.
#[test]
fn cache_hits_are_identical_to_cold_solves() {
    let profile = ModelProfile::millis_demo();
    let opts = SolverOpts::default();
    for topo in [Topology::paper_testbed(), gen_topo(gen::GenKind::Tree, 64, 64)] {
        let cm = CostModel::new(&profile, topo);
        let cold = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts);

        let mut cache = PlacementCache::new();
        let first = cache.solve(Strategy::Proposed, &cm, 10_800, &opts);
        let second = cache.solve(Strategy::Proposed, &cm, 10_800, &opts);
        assert_ne!(first.mode, SolveMode::Cached, "first solve cannot hit an empty cache");
        assert_eq!(second.mode, SolveMode::Cached);
        assert_eq!(first.plan.placement, cold.plan.placement);
        assert_eq!(second.plan.placement, cold.plan.placement);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        assert_eq!(cache.len(), 1);
    }
}

/// The cache key separates what must be separated (strategy, chunk,
/// meaningful speed drift) and quantizes away what must not matter
/// (sub-percent speed jitter).
#[test]
fn cache_key_discriminates_and_quantizes() {
    let profile = ModelProfile::millis_demo();
    let topo = Topology::builder("cache-key")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap();
    let entry = topo.entry();
    let base = PlacementCache::key(&profile, &topo, Strategy::Proposed, 10_800);
    let other_strategy = PlacementCache::key(&profile, &topo, Strategy::TwoTees, 10_800);
    let other_chunk = PlacementCache::key(&profile, &topo, Strategy::Proposed, 1);
    assert_ne!(base, other_strategy);
    assert_ne!(base, other_chunk);

    // 0.1% jitter quantizes into the same speed bucket (same key)...
    let mut jittered = topo.clone();
    jittered.set_speed(entry, topo.speed_of(entry) * 1.001);
    let jittered_key = PlacementCache::key(&profile, &jittered, Strategy::Proposed, 10_800);
    assert_eq!(base, jittered_key);

    // ...while a real 1.5× drift lands buckets away (different key)
    let mut drifted = topo.clone();
    drifted.set_speed(entry, topo.speed_of(entry) * 1.5);
    let drifted_key = PlacementCache::key(&profile, &drifted, Strategy::Proposed, 10_800);
    assert_ne!(base, drifted_key);
}

/// On generated fleets the solver stays inside its bounds: mode follows
/// the estimated path count, the result validates, satisfies the privacy
/// constraint, and the node budget is never exhausted.
#[test]
fn bounded_solve_is_valid_on_generated_fleets() {
    let profile = ModelProfile::millis_demo();
    let opts = SolverOpts::default();
    let cases = [
        gen_topo(gen::GenKind::Tree, 64, 64),
        gen_topo(gen::GenKind::Tree, 256, 256),
        gen_topo(gen::GenKind::Random, 256, 7),
    ];
    for topo in cases {
        let est = fleet::estimate_paths(&topo, Strategy::Proposed, profile.m);
        let cm = CostModel::new(&profile, topo);
        let fp = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts);
        let topo = cm.topology();
        let expected = if est <= opts.exact_threshold {
            SolveMode::Exact
        } else {
            SolveMode::Beam
        };
        assert_eq!(fp.mode, expected, "{}: paths={est}", topo.name);
        let placed = &fp.plan.placement;
        if let Err(e) = placed.validate(topo, profile.m) {
            panic!("{}: invalid placement: {e}", topo.name);
        }
        let private = placed.satisfies_privacy(topo, &profile.in_res, DELTA_RESOLUTION);
        assert!(private, "{}: placement leaks a private stage", topo.name);
        assert!(!fp.budget_exhausted, "{}: node budget exhausted", topo.name);
        assert!(fp.nodes <= opts.node_budget);

        // never worse than the always-feasible everything-on-entry plan
        let entry = Placement::single(topo.entry(), profile.m);
        let won = objective(&cm, Strategy::Proposed, placed, 10_800);
        let fallback = objective(&cm, Strategy::Proposed, &entry, 10_800);
        assert!(won <= fallback + 1e-9, "{}: beam lost to the trivial fallback", topo.name);
    }
}

/// Same spec, same solve — the beam search carries no hidden state.
#[test]
fn beam_solve_is_deterministic() {
    let profile = ModelProfile::millis_demo();
    let opts = SolverOpts::default();
    let cm = CostModel::new(&profile, gen_topo(gen::GenKind::Tree, 64, 64));
    let a = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts);
    let b = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts);
    assert_eq!(a.plan.placement, b.plan.placement);
    assert_eq!(a.nodes, b.nodes);
}

/// The incremental re-solve repairs a drifted resource without ever
/// handing back a plan worse than the standing placement costs under the
/// drifted topology, and its splice/window bookkeeping is consistent.
#[test]
fn incremental_resolve_repairs_drift() {
    let profile = ModelProfile::millis_demo();
    let opts = SolverOpts::default();
    for topo in [Topology::paper_testbed(), gen_topo(gen::GenKind::Tree, 64, 64)] {
        let cm = CostModel::new(&profile, topo.clone());
        let standing = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts).plan.placement;
        let victim = standing
            .stages
            .iter()
            .max_by_key(|st| st.range.len())
            .expect("placements have stages")
            .resource;

        let mut drifted = topo.clone();
        drifted.set_speed(victim, drifted.speed_of(victim) / 1.3);
        let cm2 = CostModel::new(&profile, drifted);
        let strat = Strategy::Proposed;
        let out = fleet::resolve_incremental(strat, &cm2, 10_800, &standing, &[victim], &opts);

        let fixed = &out.plan.placement;
        if let Err(e) = fixed.validate(cm2.topology(), profile.m) {
            panic!("{}: invalid repair: {e}", topo.name);
        }
        let in_res = &profile.in_res;
        let private = fixed.satisfies_privacy(cm2.topology(), in_res, DELTA_RESOLUTION);
        assert!(private, "{}: repair leaks a private stage", topo.name);
        assert_eq!(out.spliced, out.window.is_some(), "{}: splice bookkeeping", topo.name);
        let repaired = objective(&cm2, strat, fixed, 10_800);
        let kept = objective(&cm2, strat, &standing, 10_800);
        assert!(
            repaired <= kept + 1e-9,
            "{}: repair ({repaired:.4}s) is worse than standing ({kept:.4}s)",
            topo.name
        );
    }
}

/// An empty drift set or all-unit ratios flags nothing; a drifted stage
/// flags exactly its resource (deduplicated).
#[test]
fn drifted_resources_flags_only_drifted_stages() {
    let profile = ModelProfile::millis_demo();
    let cm = CostModel::new(&profile, Topology::paper_testbed());
    let opts = SolverOpts::default();
    let standing = fleet::solve(Strategy::Proposed, &cm, 10_800, &opts).plan.placement;
    let k = standing.stages.len();

    assert!(fleet::drifted_resources(&standing, &vec![1.0; k], 0.05).is_empty());

    let mut ratios = vec![1.0; k];
    ratios[0] = 1.3; // stage 0 runs 30% slower than predicted
    let drifted = fleet::drifted_resources(&standing, &ratios, 0.05);
    assert_eq!(drifted, vec![standing.stages[0].resource]);

    // every stage drifting still reports each resource at most once
    let all = fleet::drifted_resources(&standing, &vec![2.0; k], 0.05);
    let mut dedup = all.clone();
    dedup.dedup();
    assert_eq!(all, dedup);
}
