//! End-to-end coordinator integration: attested deployment over the
//! paper testbed, sealed streaming, numerics vs the single-chain runtime,
//! and failure injection (offline device, invalid placement).

use serdab::coordinator::{Deployment, ResourceManager};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::{Placement, Stage};
use serdab::profiler::calibrated_profile;
use serdab::runtime::pipeline::PipelineConfig;
use serdab::runtime::{default_backend, ChainExecutor};
use serdab::video::{SceneKind, VideoSource};

fn ready() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

#[test]
fn deployed_pipeline_matches_single_chain_numerics() {
    if !ready() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let model = "squeezenet";
    let info = man.model(model).unwrap();
    let profile = calibrated_profile(info);
    let cm = CostModel::paper(&profile);
    let p = plan(Strategy::TwoTees, &cm, 4);

    let rm = ResourceManager::paper_testbed();
    let dep = Deployment::deploy(&man, &rm, model, &p.placement, Some(1e9), 4).unwrap();

    let mut cam = VideoSource::new(SceneKind::Indoor, 11);
    let frames: Vec<_> = (0..4).map(|_| cam.next_frame()).collect();
    let rep = dep.run_stream(frames.clone().into_iter()).unwrap();
    assert_eq!(rep.frames, 4);

    // same frames through a local full chain: checksums must agree
    let backend = default_backend().unwrap();
    let full = ChainExecutor::load(backend.as_ref(), &man, model).unwrap();
    let mut want = 0f64;
    for f in &frames {
        want += full.run(f).unwrap().data.iter().map(|&v| v as f64).sum::<f64>();
    }
    let err = (rep.output_checksum - want).abs() / want.abs().max(1e-9);
    assert!(err < 1e-4, "checksum {} vs {}", rep.output_checksum, want);
}

#[test]
fn tcp_bridged_deployment_matches_in_process_numerics() {
    // same placement, same frames: hops over loopback TCP sockets must
    // produce bit-identical outputs to the in-process channel hops
    if !ready() {
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let model = "squeezenet";
    let info = man.model(model).unwrap();
    let rm = ResourceManager::paper_testbed();
    let tee1 = rm.topology().require("TEE1").unwrap();
    let tee2 = rm.topology().require("TEE2").unwrap();
    let cut = info.m() / 2;
    let placement = Placement {
        stages: vec![
            Stage { resource: tee1, range: 0..cut },
            Stage { resource: tee2, range: cut..info.m() },
        ],
    };
    let frames: Vec<_> = {
        let mut cam = VideoSource::new(SceneKind::Harbour, 21);
        (0..4).map(|_| cam.next_frame()).collect()
    };

    let dep = Deployment::deploy(&man, &rm, model, &placement, Some(1e9), 4).unwrap();
    let in_process = dep.run_stream(frames.clone().into_iter()).unwrap();

    let cfg = PipelineConfig { queue_cap: 4, framed: true, tcp_hops: true };
    let dep_tcp =
        Deployment::deploy_with_config(&man, &rm, model, &placement, Some(1e9), cfg).unwrap();
    let over_tcp = dep_tcp.run_stream(frames.into_iter()).unwrap();

    assert_eq!(over_tcp.frames, 4);
    let err = (over_tcp.output_checksum - in_process.output_checksum).abs()
        / in_process.output_checksum.abs().max(1e-9);
    assert!(
        err < 1e-9,
        "TCP-bridged checksum {} vs in-process {}",
        over_tcp.output_checksum,
        in_process.output_checksum
    );
}

#[test]
fn deploy_fails_for_unregistered_device() {
    if !ready() {
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let mut rm = ResourceManager::paper_testbed();
    rm.deregister("TEE2").unwrap();
    let tee1 = rm.topology().require("TEE1").unwrap();
    let tee2 = rm.topology().require("TEE2").unwrap();
    let info = man.model("squeezenet").unwrap();
    let placement = Placement {
        stages: vec![
            Stage { resource: tee1, range: 0..5 },
            Stage { resource: tee2, range: 5..info.m() },
        ],
    };
    let err = Deployment::deploy(&man, &rm, "squeezenet", &placement, None, 4);
    assert!(err.is_err(), "deploy must fail when TEE2 is offline");
}

#[test]
fn deploy_rejects_invalid_placement() {
    if !ready() {
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let rm = ResourceManager::paper_testbed();
    let tee1 = rm.topology().require("TEE1").unwrap();
    let tee2 = rm.topology().require("TEE2").unwrap();
    // gap in coverage
    let placement = Placement {
        stages: vec![
            Stage { resource: tee1, range: 0..2 },
            Stage { resource: tee2, range: 3..man.model("squeezenet").unwrap().m() },
        ],
    };
    assert!(Deployment::deploy(&man, &rm, "squeezenet", &placement, None, 4).is_err());
}

#[test]
fn pipelined_two_stage_not_slower_than_single_stage() {
    // same 8 frames: a 2-stage placement (two worker threads) should not
    // lose to 1-stage wall-clock (generous margin keeps CI stable)
    if !ready() {
        return;
    }
    let man = load_manifest(default_artifacts_dir()).unwrap();
    let model = "alexnet";
    let info = man.model(model).unwrap();
    let rm = ResourceManager::paper_testbed();
    let frames: Vec<_> = {
        let mut cam = VideoSource::new(SceneKind::Street, 5);
        (0..8).map(|_| cam.next_frame()).collect()
    };

    let tee1 = rm.topology().require("TEE1").unwrap();
    let tee2 = rm.topology().require("TEE2").unwrap();
    let one = Placement::single(tee1, info.m());
    let dep1 = Deployment::deploy(&man, &rm, model, &one, Some(1e9), 4).unwrap();
    let r1 = dep1.run_stream(frames.clone().into_iter()).unwrap();

    let cut = info.m() / 2;
    let two = Placement {
        stages: vec![
            Stage { resource: tee1, range: 0..cut },
            Stage { resource: tee2, range: cut..info.m() },
        ],
    };
    let dep2 = Deployment::deploy(&man, &rm, model, &two, Some(1e9), 4).unwrap();
    let r2 = dep2.run_stream(frames.into_iter()).unwrap();

    assert!(
        r2.total_secs < r1.total_secs * 1.10,
        "pipelining regressed: 1-stage {:.2}s vs 2-stage {:.2}s",
        r1.total_secs,
        r2.total_secs
    );
}
