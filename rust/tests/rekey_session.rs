//! Key-lifecycle acceptance: a live server rotates its channel keys —
//! periodically (`rekey_interval_secs`) and on demand (`Server::rekey`) —
//! through the zero-loss drain/hot-swap path. Every fed frame completes
//! (nothing is dropped across ≥2 epochs), the rotation is on the swap
//! record with its epoch, and the epoch counter is monotonic.
//!
//! Runs on the synthetic builder (no artifacts needed); the sealed-record
//! mechanics of an epoch bump — old-epoch records opening during the
//! handover, sequence reset, two-epochs-back rejection — are covered at
//! unit level in `crypto::channel`, and the wrapped-key handshake in
//! `crypto::keymgr` / `enclave::service`. Both scenarios live in ONE
//! #[test] so the sleep-based worker threads never compete with a
//! sibling test for cores.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use serdab::coordinator::{Server, ServerConfig, ServerEvent, StreamSpec, SyntheticBuilder};
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::topology::{LinkParams, Topology};

fn quad_topology() -> Topology {
    Topology::builder("quad-rekey")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap()
}

/// Drain events until the swap completing `epoch`, returning the Rekey
/// announcements seen on the way (panicking on failure/timeout).
fn wait_for_epoch(
    events: &Receiver<ServerEvent>,
    epoch: u32,
    timeout: Duration,
) -> Vec<ServerEvent> {
    let deadline = Instant::now() + timeout;
    let mut seen = Vec::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(!left.is_zero(), "no epoch-{epoch} swap within {timeout:?}; events: {seen:?}");
        match events.recv_timeout(left) {
            Ok(ServerEvent::SwapCompleted(ev)) if ev.key_epoch >= epoch => {
                seen.push(ServerEvent::SwapCompleted(ev));
                return seen;
            }
            Ok(ServerEvent::SwapFailed { error }) => panic!("re-key swap failed: {error}"),
            Ok(ev) => seen.push(ev),
            Err(_) => panic!("event feed closed before epoch {epoch}; events: {seen:?}"),
        }
    }
}

#[test]
fn rekey_rotates_epochs_without_frame_loss() {
    periodic_rekey_two_epochs_zero_loss();
    on_demand_rekey_bumps_epoch();
}

/// `rekey_interval_secs` drives ≥2 rotations mid-serve: every fed frame
/// still completes, and each rotation is announced + recorded with its
/// epoch.
fn periodic_rekey_two_epochs_zero_loss() {
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let mut server = Server::launch(
        profile,
        topo,
        Box::new(builder),
        ServerConfig {
            window_secs: 0.1,
            rekey_interval_secs: 0.5,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let events = server.events().unwrap();
    assert_eq!(server.key_epoch(), 0, "a fresh deployment seals under epoch 0");

    // two cameras at a comfortable rate (~25 fps aggregate against a
    // ≥50 fps pipeline) spanning several re-key intervals
    server.attach(StreamSpec::synthetic("cam-0", 0.08, 48)).unwrap();
    server.attach(StreamSpec::synthetic("cam-1", 0.08, 48)).unwrap();

    let seen = wait_for_epoch(&events, 2, Duration::from_secs(15));
    assert!(server.key_epoch() >= 2, "status must report the rotated epoch");

    // every rotation was announced before its swap, with matching epochs,
    // and the epoch sequence on completed swaps is monotonically rising
    let announced: Vec<u32> = seen
        .iter()
        .filter_map(|ev| match ev {
            ServerEvent::Rekey { epoch, .. } => Some(*epoch),
            _ => None,
        })
        .collect();
    let completed: Vec<u32> = seen
        .iter()
        .filter_map(|ev| match ev {
            ServerEvent::SwapCompleted(ev) => Some(ev.key_epoch),
            _ => None,
        })
        .collect();
    assert!(announced.len() >= 2, "expected ≥2 Rekey announcements: {seen:?}");
    assert!(announced.windows(2).all(|w| w[0] < w[1]), "epochs must rise: {announced:?}");
    assert!(
        completed.windows(2).all(|w| w[0] < w[1]),
        "completed swap epochs must rise: {completed:?}"
    );

    // the synthetic builder attests nothing — status says so (the
    // attested DeployBuilder path reports real cache counters here)
    let st = server.status();
    assert_eq!(st.attest_cache, None);
    assert_eq!(st.key_epoch, server.key_epoch());

    // zero loss: the drain guarantees every fed frame completed, across
    // every epoch handover
    let report = server.shutdown().unwrap();
    assert!(report.swaps.len() >= 2, "both rotations are on the swap record");
    assert!(
        report.swaps.iter().any(|s| s.key_epoch >= 2),
        "swap record must carry the rotated epoch: {:?}",
        report.swaps
    );
    assert_eq!(report.frames_dropped, 0, "re-keying must drain, never drop");
    assert_eq!(report.sink_errors, 0);
    let total_fed: u64 = report.streams.iter().map(|s| s.fed).sum();
    assert_eq!(report.frames, total_fed, "every fed frame drained to the sink");
    for s in &report.streams {
        assert_eq!(s.completed, s.fed, "stream {} lost frames across re-keys", s.label);
    }
}

/// With no periodic schedule, `Server::rekey` rotates exactly when asked.
fn on_demand_rekey_bumps_epoch() {
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let mut server = Server::launch(
        profile,
        topo,
        Box::new(builder),
        ServerConfig { window_secs: 0.1, ..ServerConfig::default() },
    )
    .unwrap();
    let events = server.events().unwrap();
    server.attach(StreamSpec::synthetic("cam-0", 0.05, 40)).unwrap();

    // no schedule: serving alone never rotates
    std::thread::sleep(Duration::from_millis(400));
    assert_eq!(server.key_epoch(), 0, "no re-key without a request or schedule");

    server.rekey();
    wait_for_epoch(&events, 1, Duration::from_secs(10));
    assert_eq!(server.key_epoch(), 1);

    server.rekey();
    wait_for_epoch(&events, 2, Duration::from_secs(10));
    assert_eq!(server.key_epoch(), 2);

    let report = server.shutdown().unwrap();
    assert_eq!(report.frames_dropped, 0, "on-demand re-keys must not drop frames");
    let total_fed: u64 = report.streams.iter().map(|s| s.fed).sum();
    assert_eq!(report.frames, total_fed);
}
