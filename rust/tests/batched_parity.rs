//! Batched-GEMM determinism: running N frames stacked along dim 0 in ONE
//! conv/dense call must be **bitwise** identical to the N batch-1 runs it
//! coalesces — per frame, byte for byte.
//!
//! This is the contract the micro-batching scheduler
//! (`runtime::pipeline`) leans on: it may coalesce any frames that happen
//! to be queued, so serving results must not depend on *which* batch a
//! frame landed in. It holds structurally — im2col rows are
//! frame-independent and every output element is bias + a fixed
//! ascending-k accumulation computed by exactly one worker — and this
//! suite enforces it over randomized shapes, batch sizes 2/3/8, and
//! worker counts 1/4 (CI runs the whole file under `SERDAB_THREADS=1`
//! and `=4` as an explicit matrix).

use serdab::runtime::backend::reference::ops;
use serdab::runtime::backend::reference::zoo::Pad;
use serdab::runtime::{Scratch, Tensor};
use serdab::util::rng::Rng;

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    Tensor::new(shape.to_vec(), data).unwrap()
}

/// Stack batch-1 frames along dim 0 — what the service's batched path
/// does before its single GEMM.
fn stack(frames: &[Tensor]) -> Tensor {
    let mut shape = frames[0].shape.clone();
    shape[0] = frames.len();
    let mut data = Vec::with_capacity(frames.iter().map(|f| f.data.len()).sum());
    for f in frames {
        data.extend_from_slice(&f.data);
    }
    Tensor::new(shape, data).unwrap()
}

/// Split a batch-N output into its per-frame byte images.
fn per_frame_bytes(out: &Tensor, n: usize) -> Vec<Vec<u8>> {
    let bytes = out.to_le_bytes();
    let per = bytes.len() / n;
    (0..n).map(|i| bytes[i * per..(i + 1) * per].to_vec()).collect()
}

#[test]
fn batched_conv_is_bitwise_equal_to_sequential() {
    let mut rng = Rng::new(0xba7c4);
    for &threads in &[1usize, 4] {
        let mut scratch = Scratch::with_threads(threads);
        for &batch in &[2usize, 3, 8] {
            for case in 0..6 {
                let k = [1usize, 3, 5][rng.range(0, 3)];
                let h = rng.range(k, k + 11);
                let w = rng.range(k, k + 11);
                let cin = rng.range(1, 17);
                let cout = rng.range(1, 33);
                let stride = rng.range(1, 3);
                let pad = if rng.bool(0.5) { Pad::Same } else { Pad::Valid };
                let relu = rng.bool(0.5);

                let wt = rand_tensor(&mut rng, &[k, k, cin, cout]);
                let b = rand_tensor(&mut rng, &[cout]);
                let frames: Vec<Tensor> =
                    (0..batch).map(|_| rand_tensor(&mut rng, &[1, h, w, cin])).collect();

                let solo: Vec<Vec<u8>> = frames
                    .iter()
                    .map(|f| {
                        let y = ops::conv2d_scratch(f, &wt, &b, stride, &pad, relu, &mut scratch)
                            .unwrap();
                        let bytes = y.to_le_bytes();
                        scratch.give(y);
                        bytes
                    })
                    .collect();

                let y = ops::conv2d_scratch(
                    &stack(&frames),
                    &wt,
                    &b,
                    stride,
                    &pad,
                    relu,
                    &mut scratch,
                )
                .unwrap();
                assert_eq!(y.shape[0], batch, "batch dim must survive conv");
                let coalesced = per_frame_bytes(&y, batch);
                scratch.give(y);

                for (i, (got, want)) in coalesced.iter().zip(&solo).enumerate() {
                    assert_eq!(
                        got, want,
                        "conv frame {i} diverged (threads={threads} B={batch} case {case} \
                         h={h} w={w} cin={cin} k={k} cout={cout} s={stride} {pad:?} relu={relu})"
                    );
                }
            }
        }
    }
}

#[test]
fn batched_dense_is_bitwise_equal_to_sequential() {
    let mut rng = Rng::new(0xd0_5e);
    for &threads in &[1usize, 4] {
        let mut scratch = Scratch::with_threads(threads);
        for &batch in &[2usize, 3, 8] {
            for case in 0..6 {
                let fin = rng.range(1, 300);
                let fout = rng.range(1, 70);
                let relu = rng.bool(0.5);
                let w = rand_tensor(&mut rng, &[fin, fout]);
                let b = rand_tensor(&mut rng, &[fout]);
                let frames: Vec<Tensor> =
                    (0..batch).map(|_| rand_tensor(&mut rng, &[1, fin])).collect();

                let solo: Vec<Vec<u8>> = frames
                    .iter()
                    .map(|f| {
                        let y = ops::dense_scratch(f, &w, &b, relu, &mut scratch).unwrap();
                        let bytes = y.to_le_bytes();
                        scratch.give(y);
                        bytes
                    })
                    .collect();

                let y = ops::dense_scratch(&stack(&frames), &w, &b, relu, &mut scratch).unwrap();
                assert_eq!(y.shape, vec![batch, fout]);
                let coalesced = per_frame_bytes(&y, batch);
                scratch.give(y);

                for (i, (got, want)) in coalesced.iter().zip(&solo).enumerate() {
                    assert_eq!(
                        got, want,
                        "dense frame {i} diverged (threads={threads} B={batch} case {case} \
                         fin={fin} fout={fout} relu={relu})"
                    );
                }
            }
        }
    }
}

#[test]
fn env_thread_count_is_bit_invisible_for_batched_runs() {
    // `Scratch::new()` reads SERDAB_THREADS — the CI matrix runs this
    // file at 1 and 4 workers, and the batched results must not move.
    let mut rng = Rng::new(0x5ead);
    let x = rand_tensor(&mut rng, &[8, 14, 14, 12]);
    let w = rand_tensor(&mut rng, &[3, 3, 12, 24]);
    let b = rand_tensor(&mut rng, &[24]);

    let mut env_scratch = Scratch::new();
    let mut one = Scratch::with_threads(1);
    let ye = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut env_scratch).unwrap();
    let y1 = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut one).unwrap();
    assert_eq!(
        ye.to_le_bytes(),
        y1.to_le_bytes(),
        "batched conv must be identical under any SERDAB_THREADS"
    );
}
