//! Acceptance for the session-oriented `Server`: a multi-enclave
//! deployment serves live camera streams, a mid-run stage slowdown is
//! observed by the *online* monitor (`MonitorVerdict::Repartition`), and
//! the server re-solves against the observed stage times and hot-swaps to
//! a placement whose measured post-swap throughput recovers — with the
//! DES (fed the same arrival schedule and the ground-truth slowdown)
//! agreeing on what that throughput should be.
//!
//! Everything runs on the synthetic builder (workers execute the cost
//! model's nominal service times × an injectable per-resource factor), so
//! the test needs no model artifacts — the configuration
//! `tests/pipeline_vs_sim.rs` validates against the DES. Both scenarios
//! live in ONE #[test] so the sleep-based worker threads never compete
//! with a sibling test for cores.

use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

use serdab::coordinator::{
    Server, ServerConfig, ServerEvent, StreamSpec, SwapEvent, SyntheticBuilder,
};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::profiler::{DeviceKind, ModelProfile};
use serdab::runtime::{LoadGen, LoadGenConfig};
use serdab::sim::simulate_schedule;
use serdab::topology::{LinkParams, Topology};

/// Four edge devices, one enclave each, fast LAN — a placement-rich
/// graph where re-solving has somewhere to move work.
fn quad_topology() -> Topology {
    Topology::builder("quad-live")
        .resource("T0", DeviceKind::Tee, 0)
        .resource("T1", DeviceKind::Tee, 1)
        .resource("T2", DeviceKind::Tee, 2)
        .resource("T3", DeviceKind::Tee, 3)
        .default_link(LinkParams { bandwidth_bps: 1e9, rtt_secs: 1e-4 })
        .camera(0)
        .sink(0)
        .build()
        .unwrap()
}

/// Drain events until a completed swap (panicking on failure/timeout).
fn wait_for_swap(events: &Receiver<ServerEvent>, timeout: Duration) -> (SwapEvent, Vec<ServerEvent>) {
    let deadline = Instant::now() + timeout;
    let mut seen = Vec::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        assert!(!left.is_zero(), "no hot-swap within {timeout:?}; events: {seen:?}");
        match events.recv_timeout(left) {
            Ok(ServerEvent::SwapCompleted(ev)) => return (ev, seen),
            Ok(ServerEvent::SwapFailed { error }) => panic!("hot-swap failed: {error}"),
            Ok(ev) => seen.push(ev),
            Err(_) => panic!("event feed closed before a hot-swap; events: {seen:?}"),
        }
    }
}

#[test]
fn server_sessions_attach_detach_and_hot_swap_on_drift() {
    attach_detach_mid_run();
    drift_triggers_repartition_and_throughput_recovers();
}

/// Streams join and leave a live server without disturbing each other,
/// and every frame fed is attributed back to its stream.
fn attach_detach_mid_run() {
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let mut server = Server::launch(
        profile,
        topo,
        Box::new(builder),
        ServerConfig { window_secs: 0.1, ..ServerConfig::default() },
    )
    .unwrap();

    // two long-lived cameras at a comfortable rate (~25 fps aggregate
    // against a ≥50 fps pipeline)
    let s0 = server.attach(StreamSpec::synthetic("cam-0", 0.08, 64)).unwrap();
    let s1 = server.attach(StreamSpec::synthetic("cam-1", 0.08, 64)).unwrap();
    std::thread::sleep(Duration::from_millis(400));

    // a third camera joins mid-run...
    let s2 = server.attach(StreamSpec::synthetic("cam-2", 0.05, 64)).unwrap();
    std::thread::sleep(Duration::from_millis(400));
    // ...and leaves again; its frames completed, the others kept serving
    let r2 = server.detach(s2.id()).unwrap();
    assert!(r2.fed >= 4, "cam-2 barely fed: {r2:?}");

    std::thread::sleep(Duration::from_millis(300));
    let report = server.shutdown().unwrap();

    assert_eq!(report.swaps.len(), 0, "healthy serve must not repartition");
    assert_eq!(report.sink_errors, 0);
    assert_eq!(report.frames_dropped, 0, "healthy serve must not drop frames");
    let total_fed: u64 = report.streams.iter().map(|s| s.fed).sum();
    assert_eq!(
        report.frames, total_fed,
        "every fed frame must drain to the sink across generations"
    );
    for s in &report.streams {
        assert_eq!(s.completed, s.fed, "stream {} lost frames: {s:?}", s.label);
        assert!(s.mean_latency_secs > 0.0, "stream {} latency untracked", s.label);
    }
    // all three streams are on record with their identities intact, and
    // the long-lived ones kept serving after cam-2 left
    let by_id = |id: u32| report.streams.iter().find(|s| s.id == id).unwrap();
    assert_eq!(by_id(s2.id()).label, "cam-2");
    assert!(by_id(s0.id()).fed > r2.fed / 2, "cam-0 starved: {report:?}");
    assert!(by_id(s1.id()).fed > 0, "cam-1 starved: {report:?}");
}

/// The §V loop end-to-end: slowdown → online Repartition verdict →
/// re-solve from observed times → hot-swap → measured throughput
/// recovers, agreeing with the DES run on the same arrival schedule.
fn drift_triggers_repartition_and_throughput_recovers() {
    let profile = ModelProfile::millis_demo();
    let topo = quad_topology();
    let mut builder = SyntheticBuilder::new(profile.clone(), topo.clone());
    let slow = builder.slowdown("T0");

    // reference plan (the server solves the same inputs the same way)
    let cm = CostModel::new(&profile, topo.clone());
    let p0 = plan(Strategy::Proposed, &cm, 10_800);
    let stage0_nominal = p0.cost.stage_secs[0];
    let block0 = profile.tee.block_secs[0];
    const FACTOR: f64 = 4.0;
    // offered load sits between the slowed capacity (entry stage × 4
    // bottlenecks the old placement) and the post-swap capacity (T0
    // shrunk to one block, still 4× slow): degradation is visible, and
    // recovery is possible — but only through a re-partition.
    let slowed_cap = 1.0 / (stage0_nominal * FACTOR);
    let post_cap = 1.0 / (block0 * FACTOR);
    assert!(post_cap > slowed_cap * 1.5, "test topology lost its headroom");
    let offered = 0.5 * (slowed_cap + post_cap);
    let streams = 2u32;
    let interval = streams as f64 / offered;

    let mut server = Server::launch(
        profile.clone(),
        topo.clone(),
        Box::new(builder),
        ServerConfig {
            strategy: Strategy::Proposed,
            window_secs: 0.15,
            drift_threshold: 0.5,
            patience: 2,
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let events = server.events().unwrap();
    let placement_before = server.placement().expect("live generation");
    assert!(placement_before.stages.len() >= 3, "multi-enclave placement expected");

    for i in 0..streams {
        let mut spec = StreamSpec::synthetic(format!("cam-{i}"), interval, 64);
        spec.seed = 100 + i as u64;
        server.attach(spec).unwrap();
    }

    // phase 1: healthy serving — windows observe, nothing fires
    std::thread::sleep(Duration::from_millis(700));
    assert_eq!(server.swaps().len(), 0, "no drift yet, no swap");

    // phase 2: the entry enclave degrades 4× (thermal throttling, a noisy
    // co-tenant — the hardware is slow from now on, including after any
    // redeploy)
    *slow.lock().unwrap() = FACTOR;
    let (swap, pre_events) = wait_for_swap(&events, Duration::from_secs(15));

    // the verdict attributed the drift and the re-solve moved work off T0
    assert!(
        swap.observed > swap.predicted * 2.0,
        "observed {:.4}s should dwarf predicted {:.4}s",
        swap.observed,
        swap.predicted
    );
    assert_ne!(swap.from, swap.to, "re-solve must change the placement");
    let placement_after = server.placement().expect("post-swap generation");
    assert!(
        placement_after.stages[0].range.len() < placement_before.stages[0].range.len(),
        "re-solve should shrink the slowed entry enclave's share: {} → {}",
        swap.from,
        swap.to
    );
    // degradation was visible online before the swap fired
    let degraded = pre_events.iter().any(|ev| match ev {
        ServerEvent::Window { throughput_fps, .. } => *throughput_fps < 0.85 * offered,
        _ => false,
    });
    assert!(degraded, "no pre-swap window showed degraded throughput: {pre_events:?}");

    // phase 3: recovery — let the backlog drain, then measure a window
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let st = server.status();
        let fed: u64 = st.streams.iter().map(|s| s.fed).sum();
        if fed.saturating_sub(st.frames_completed) <= 8 || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    let t1 = (server.status().frames_completed, Instant::now());
    std::thread::sleep(Duration::from_millis(1200));
    let t2 = (server.status().frames_completed, Instant::now());
    let measured = (t2.0 - t1.0) as f64 / (t2.1 - t1.1).as_secs_f64();

    // the DES, given the same arrival schedule and the ground-truth
    // slowdown (T0 four times slower), predicts the post-swap throughput;
    // the measured window must agree (and must have recovered to the
    // offered rate, which the slowed placement could not carry)
    let mut true_topo = topo.clone();
    let t0 = true_topo.require("T0").unwrap();
    true_topo.set_speed(t0, 1.0 / FACTOR);
    let cm_true = CostModel::new(&profile, true_topo);
    let lg = LoadGen::new(&LoadGenConfig {
        streams,
        frames_per_stream: 40,
        interval_secs: interval,
        poisson: false,
        seed: 9,
    });
    let des = simulate_schedule(&cm_true, &placement_after, lg.arrivals(), 4);
    let des_throughput = des.throughput();
    assert!(
        measured > 0.8 * offered,
        "post-swap throughput did not recover: measured {measured:.1} fps, offered {offered:.1} \
         fps (slowed capacity was {slowed_cap:.1})"
    );
    let rel = (measured - des_throughput).abs() / des_throughput;
    assert!(
        rel < 0.30,
        "measured {measured:.1} fps vs DES {des_throughput:.1} fps ({:.0}% off)",
        rel * 100.0
    );

    let report = server.shutdown().unwrap();
    assert!(!report.swaps.is_empty(), "the swap must be on record");
    assert_eq!(report.segments.len(), report.swaps.len() + 1, "one generation per swap + final");
    assert_eq!(report.frames_dropped, 0, "hot-swap must drain, not drop");
    let total_fed: u64 = report.streams.iter().map(|s| s.fed).sum();
    assert_eq!(
        report.frames, total_fed,
        "hot-swap must drain in-flight frames, not drop them"
    );
    for s in &report.streams {
        assert_eq!(s.completed, s.fed, "stream {} lost frames across the swap", s.label);
    }
}
