//! Fleet-solver bench (DESIGN.md §18): cold solve and incremental
//! re-solve wall time as the topology grows from the paper's 5-resource
//! testbed to a 1024-resource random fleet, plus placement-cache
//! behaviour.
//!
//! Per size: a cold [`fleet::solve`] is timed (best of several reps),
//! the same solve is repeated through a [`PlacementCache`] to prove a
//! hit returns the identical placement, and a drift on the busiest
//! stage's resource is repaired with [`fleet::resolve_incremental`] —
//! the incremental time is compared against the cold time.
//!
//! `--json` writes `BENCH_solver.json` at the repo root — the CI
//! perf-trend lane (`scripts/check_bench.sh`) gates on it: cached
//! placements must equal their cold solves everywhere, the 256-resource
//! incremental re-solve must be ≥ 5× faster than cold, and the
//! 1024-resource cold solve must finish under 5 s without exhausting the
//! node budget.

use std::time::Instant;

use anyhow::Result;
use serdab::figures::Table;
use serdab::placement::cost::CostModel;
use serdab::placement::fleet::{self, PlacementCache, SolveMode, SolverOpts};
use serdab::placement::strategies::Strategy;
use serdab::profiler::ModelProfile;
use serdab::topology::{gen, Topology};
use serdab::util::json::{arr, num, obj, s, Json};

const CHUNK: u64 = 10_800;

struct Row {
    label: String,
    resources: usize,
    cold_ms: f64,
    incr_ms: f64,
    speedup: f64,
    mode: &'static str,
    nodes: u64,
    budget_exhausted: bool,
    cache_hit: bool,
    cache_bitwise: bool,
    spliced: bool,
}

/// Bench one topology: cold solve, cache round-trip, drift + incremental
/// re-solve. `reps` > 1 takes the best wall time (small solves jitter).
fn bench_topo(label: &str, topo: &Topology, profile: &ModelProfile, reps: usize) -> Result<Row> {
    let opts = SolverOpts::default();
    let cm = CostModel::new(profile, topo.clone());

    let mut cold_ms = f64::INFINITY;
    let mut fp = None;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        let f = fleet::solve(Strategy::Proposed, &cm, CHUNK, &opts);
        cold_ms = cold_ms.min(t.elapsed().as_secs_f64() * 1e3);
        fp = Some(f);
    }
    let fp = fp.expect("at least one rep ran");
    fp.plan
        .placement
        .validate(topo, profile.m)
        .map_err(|e| anyhow::anyhow!("{label}: cold solve produced an invalid placement: {e}"))?;

    // cache round-trip: second solve must hit and return the identical
    // placement
    let mut cache = PlacementCache::new();
    let first = cache.solve(Strategy::Proposed, &cm, CHUNK, &opts);
    let second = cache.solve(Strategy::Proposed, &cm, CHUNK, &opts);
    let cache_hit = second.mode == SolveMode::Cached;
    let cache_bitwise = first.plan.placement == fp.plan.placement
        && second.plan.placement == fp.plan.placement;

    // drift: the busiest stage's resource slows by 30%, the monitor's
    // recalibration would rescale its speed grade accordingly
    let standing = fp.plan.placement.clone();
    let victim = standing
        .stages
        .iter()
        .max_by_key(|st| st.range.len())
        .expect("placements have stages")
        .resource;
    let mut drifted_topo = topo.clone();
    drifted_topo.set_speed(victim, drifted_topo.speed_of(victim) / 1.3);
    let cm2 = CostModel::new(profile, drifted_topo);

    let mut incr_ms = f64::INFINITY;
    let mut out = None;
    for _ in 0..reps.max(1) {
        let t = Instant::now();
        let o = fleet::resolve_incremental(
            Strategy::Proposed,
            &cm2,
            CHUNK,
            &standing,
            &[victim],
            &opts,
        );
        incr_ms = incr_ms.min(t.elapsed().as_secs_f64() * 1e3);
        out = Some(o);
    }
    let out = out.expect("at least one rep ran");
    out.plan
        .placement
        .validate(cm2.topology(), profile.m)
        .map_err(|e| anyhow::anyhow!("{label}: incremental repair invalid: {e}"))?;

    Ok(Row {
        label: label.to_string(),
        resources: topo.len(),
        cold_ms,
        incr_ms,
        speedup: cold_ms / incr_ms.max(1e-6),
        mode: fp.mode.name(),
        nodes: fp.nodes,
        budget_exhausted: fp.budget_exhausted,
        cache_hit,
        cache_bitwise,
        spliced: out.spliced,
    })
}

fn main() -> Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    println!("# fleet-solver bench\n");

    let profile = ModelProfile::millis_demo();
    let tree = |n: usize, seed: u64| {
        gen::generate(&gen::GenSpec { kind: gen::GenKind::Tree, resources: n, seed })
    };
    let topos: Vec<(String, Topology, usize)> = vec![
        ("paper-5".into(), Topology::paper_testbed(), 20),
        ("tree-64".into(), tree(64, 64)?, 10),
        ("tree-256".into(), tree(256, 256)?, 5),
        (
            "rand-1024".into(),
            gen::generate(&gen::GenSpec {
                kind: gen::GenKind::Random,
                resources: 1024,
                seed: 1024,
            })?,
            2,
        ),
    ];

    // warm-up: page in the solver code paths once
    let warm = CostModel::new(&profile, Topology::paper_testbed());
    fleet::solve(Strategy::Proposed, &warm, CHUNK, &SolverOpts::default());

    let mut rows = Vec::new();
    for (label, topo, reps) in &topos {
        rows.push(bench_topo(label, topo, &profile, *reps)?);
    }

    let mut table = Table::new(&[
        "topology", "resources", "mode", "nodes", "cold", "incremental", "speedup", "cache",
    ]);
    for r in &rows {
        table.row(vec![
            r.label.clone(),
            format!("{}", r.resources),
            r.mode.to_string(),
            format!("{}", r.nodes),
            format!("{:.2} ms", r.cold_ms),
            format!("{:.2} ms", r.incr_ms),
            format!("{:.1}×", r.speedup),
            if r.cache_hit && r.cache_bitwise { "hit=cold".into() } else { "MISS".to_string() },
        ]);
    }
    println!("{}", table.render());

    let all_bitwise = rows.iter().all(|r| r.cache_hit && r.cache_bitwise);
    println!("cache hits bitwise-equal to cold solves: {all_bitwise}");

    if json_mode {
        // machine class stamp: scripts/check_bench.sh only enforces the
        // wall-time floors when the recorded class matches the checking
        // host (`$(uname -m)-$(nproc)cpu`) or STRICT=1 forces them
        let ncpu = std::thread::available_parallelism().map_or(1, |n| n.get());
        let machine = format!("{}-{ncpu}cpu", std::env::consts::ARCH);
        let json = obj(vec![
            ("bench", s("solver_bench")),
            ("generator", s("cargo bench --bench solver_bench -- --json")),
            ("machine", s(&machine)),
            ("chunk", num(CHUNK as f64)),
            ("cache_bitwise", Json::Bool(all_bitwise)),
            (
                "rows",
                arr(rows
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("topology", s(&r.label)),
                            ("resources", num(r.resources as f64)),
                            ("mode", s(r.mode)),
                            ("nodes", num(r.nodes as f64)),
                            ("budget_exhausted", Json::Bool(r.budget_exhausted)),
                            ("cold_ms", Json::Num(r.cold_ms)),
                            ("incr_ms", Json::Num(r.incr_ms)),
                            ("speedup", Json::Num(r.speedup)),
                            ("cache_hit", Json::Bool(r.cache_hit)),
                            ("cache_bitwise", Json::Bool(r.cache_bitwise)),
                            ("spliced", Json::Bool(r.spliced)),
                        ])
                    })
                    .collect()),
            ),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .join("BENCH_solver.json");
        std::fs::write(&path, json.to_string_pretty() + "\n")?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
