//! Hot-path microbenchmarks (§Perf notes in crypto/gcm.rs): the three components
//! on the per-frame critical path of the live pipeline —
//!   1. AES-128-GCM seal+open of boundary tensors (crypto),
//!   2. Tensor ⇄ wire-bytes bridging + block execution (runtime, on the
//!      backend `SERDAB_BACKEND` selects — reference by default),
//!   3. record framing + channel sealing (net + channel).
//!
//! Run before/after each optimization; the table is the §Perf log's input.

use serdab::crypto::channel::Channel;
use serdab::crypto::gcm::AesGcm;
use serdab::figures::{BenchTimer, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::runtime::{default_backend, ChainExecutor, Tensor};
use serdab::util::fmt_bytes;

fn main() -> anyhow::Result<()> {
    println!("# hot-path microbench\n");
    let timer = BenchTimer::new(3, 21);
    let mut table = Table::new(&["component", "payload", "median", "throughput"]);

    // --- 1. GCM on representative boundary-tensor sizes -------------------
    let gcm = AesGcm::new(b"hotpath-bench-ke");
    for &kb in &[64usize, 400, 1600] {
        let bytes = kb * 1024;
        let mut buf = vec![3u8; bytes];
        let m = timer.measure(|| {
            let tag = gcm.seal(&[1u8; 12], b"bench", &mut buf);
            gcm.open(&[1u8; 12], b"bench", &mut buf, &tag).unwrap();
        });
        table.row(vec![
            "gcm seal+open".into(),
            fmt_bytes(bytes as u64),
            format!("{m}"),
            format!("{:.0} MB/s", 2.0 * bytes as f64 / m.median_secs / 1e6),
        ]);
    }

    // --- 2. channel record seal (incl. nonce + framing) -------------------
    {
        let mut ch = Channel::new(b"bench-secret", true);
        let payload = vec![7u8; 400 * 1024];
        let m = timer.measure(|| std::hint::black_box(ch.tx.seal_record(&payload)));
        table.row(vec![
            "channel seal_record".into(),
            fmt_bytes(payload.len() as u64),
            format!("{m}"),
            format!("{:.0} MB/s", payload.len() as f64 / m.median_secs / 1e6),
        ]);
    }

    // --- 3. tensor bridge + block execution --------------------------------
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let man = load_manifest(&dir)?;
        let backend = default_backend()?;
        let info = man.model("squeezenet")?;
        let chain = ChainExecutor::load(backend.as_ref(), &man, "squeezenet")?;
        let input =
            Tensor::from_bin_file(&man.path(&info.golden_input), man.input_shape.clone())?;

        let shape = input.shape.clone();
        let m = timer.measure(|| {
            // full round-trip: serialize (every sealed hop does this) and
            // deserialize (every opened record does)
            let wire = input.to_le_bytes();
            std::hint::black_box(Tensor::from_le_bytes(&wire, shape.clone()).unwrap())
        });
        table.row(vec![
            "tensor→wire→tensor".into(),
            fmt_bytes(input.byte_len() as u64),
            format!("{m}"),
            format!("{:.0} MB/s", 2.0 * input.byte_len() as f64 / m.median_secs / 1e6),
        ]);

        let b0 = &chain.blocks[0];
        let m = timer.measure(|| std::hint::black_box(b0.run(&input).unwrap()));
        table.row(vec![
            format!("block run [{}]", b0.name),
            fmt_bytes(input.byte_len() as u64),
            format!("{m}"),
            String::new(),
        ]);

        let slow = BenchTimer::new(1, 5);
        let m = slow.measure(|| std::hint::black_box(chain.run(&input).unwrap()));
        table.row(vec![
            "full chain (10 blocks)".into(),
            fmt_bytes(input.byte_len() as u64),
            format!("{m}"),
            String::new(),
        ]);
    } else {
        eprintln!("(artifacts missing — runtime rows skipped)");
    }

    println!("{}", table.render());
    Ok(())
}
