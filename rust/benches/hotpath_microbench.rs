//! Hot-path microbenchmarks (DESIGN.md §15 Perf log): the components on
//! the per-frame critical path of the live pipeline —
//!   1. AES-128-GCM seal+open of boundary tensors (crypto), plus the
//!      sealed-hop lane: dispatched (AES-NI + CLMUL) vs scalar GCM on the
//!      same records *in the same run* — the before/after pair the ≥3×
//!      crypto target is judged on, with a bitwise parity check,
//!   2. secure-channel record sealing + coalesced framing (net + channel),
//!   3. block execution on the reference backend's GEMM core, measured
//!      against the retained pre-GEMM `naive` kernels *in the same run*
//!      (the before/after pair the ≥3× block-exec target is judged on),
//!      plus the resident-pool lane (4 pooled workers vs the 1-worker
//!      GEMM row, with in-run bitwise parity across pool sizes) and the
//!      packed-B lane (prepacked weight panels vs the pack-free path,
//!      in-run bitwise parity — DESIGN.md §20),
//!   4. tensor ⇄ wire-bytes bridging and real artifact blocks when the
//!      artifacts directory exists.
//!
//! `--json` additionally writes `BENCH_hotpath.json` at the repo root
//! (component → payload → median ns + throughput, the block-exec speedup,
//! and the sealed-hop / compute-pool / packed-B lanes
//! `scripts/check_bench.sh` gates), so the perf trajectory is
//! machine-readable PR-over-PR; CI uploads it as a build artifact.

use serdab::crypto::channel::Channel;
use serdab::crypto::gcm::AesGcm;
use serdab::figures::{BenchTimer, Measurement, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::net::framing::{FrameType, FrameWriter};
use serdab::runtime::backend::reference::gemm;
use serdab::runtime::backend::reference::ops::{self, naive};
use serdab::runtime::backend::reference::zoo::Pad;
use serdab::runtime::{default_backend, ChainExecutor, Scratch, Tensor};
use serdab::util::fmt_bytes;
use serdab::util::json::{arr, num, obj, s, Json};
use serdab::util::rng::Rng;

/// One report row: component, payload label, measurement, throughput.
struct Row {
    component: String,
    payload: String,
    m: Measurement,
    throughput: String,
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    Tensor::new(shape.to_vec(), data).unwrap()
}

fn gflops(flops: usize, m: &Measurement) -> String {
    format!("{:.2} GFLOP/s", flops as f64 / m.median_secs / 1e9)
}

fn main() -> anyhow::Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    println!("# hot-path microbench\n");
    let timer = BenchTimer::new(3, 21);
    let mut rows: Vec<Row> = Vec::new();

    // --- 1. GCM on representative boundary-tensor sizes -------------------
    let gcm = AesGcm::new(b"hotpath-bench-ke");
    for &kb in &[64usize, 400, 1600] {
        let bytes = kb * 1024;
        let mut buf = vec![3u8; bytes];
        let m = timer.measure(|| {
            let tag = gcm.seal(&[1u8; 12], b"bench", &mut buf);
            gcm.open(&[1u8; 12], b"bench", &mut buf, &tag).unwrap();
        });
        rows.push(Row {
            component: "gcm seal+open".into(),
            payload: fmt_bytes(bytes as u64),
            m,
            throughput: format!("{:.0} MB/s", 2.0 * bytes as f64 / m.median_secs / 1e6),
        });
    }

    // --- 1b. sealed hop: dispatched vs scalar GCM in the same run ---------
    // The crypto lane scripts/check_bench.sh gates on BENCH_hotpath.json:
    // parity fails on any machine, the speedup floor binds on AES-NI
    // hosts (the scalar path IS the dispatched path without AES-NI, so
    // the ratio is ~1 there by construction).
    let aesni = serdab::crypto::gcm::aesni_available();
    let scalar = AesGcm::new_scalar(b"hotpath-bench-ke");
    let mut hop_rows: Vec<Json> = Vec::new();
    let mut hop_parity = true;
    for &(label, bytes) in &[("64 KiB", 64usize << 10), ("1 MiB", 1usize << 20)] {
        let mut buf = vec![0x5au8; bytes];
        let mut buf2 = buf.clone();
        let t_fast = gcm.seal(&[9u8; 12], b"hop", &mut buf);
        let t_slow = scalar.seal(&[9u8; 12], b"hop", &mut buf2);
        hop_parity &= t_fast == t_slow && buf == buf2;
        scalar.open(&[9u8; 12], b"hop", &mut buf, &t_fast).unwrap();

        let m_fast = timer.measure(|| {
            let tag = gcm.seal(&[9u8; 12], b"hop", &mut buf);
            gcm.open(&[9u8; 12], b"hop", &mut buf, &tag).unwrap();
        });
        let m_slow = timer.measure(|| {
            let tag = scalar.seal(&[9u8; 12], b"hop", &mut buf);
            scalar.open(&[9u8; 12], b"hop", &mut buf, &tag).unwrap();
        });
        let speedup = m_slow.median_secs / m_fast.median_secs;
        for (path, m) in [("dispatched", m_fast), ("scalar", m_slow)] {
            rows.push(Row {
                component: format!("sealed hop ({path})"),
                payload: label.into(),
                m,
                throughput: format!("{:.2} GB/s", 2.0 * bytes as f64 / m.median_secs / 1e9),
            });
        }
        println!("sealed hop {label}: {speedup:.2}× dispatched vs scalar (aesni={aesni})");
        hop_rows.push(obj(vec![
            ("payload", s(label)),
            ("bytes", num(bytes as f64)),
            ("dispatched_gbps", Json::Num(2.0 * bytes as f64 / m_fast.median_secs / 1e9)),
            ("scalar_gbps", Json::Num(2.0 * bytes as f64 / m_slow.median_secs / 1e9)),
            ("speedup", Json::Num(speedup)),
        ]));
    }

    // --- 2. channel record seal (reused buffer) + coalesced framing -------
    {
        let mut ch = Channel::new(b"bench-secret", true);
        let payload = vec![7u8; 400 * 1024];
        let mut rec = Vec::new();
        let m = timer.measure(|| {
            ch.tx.seal_record_into(&payload, &mut rec).unwrap();
            std::hint::black_box(rec.len());
        });
        rows.push(Row {
            component: "channel seal_record".into(),
            payload: fmt_bytes(payload.len() as u64),
            m,
            throughput: format!("{:.0} MB/s", payload.len() as f64 / m.median_secs / 1e6),
        });

        let mut fw = FrameWriter::new(std::io::sink());
        let m = timer.measure(|| fw.send(FrameType::Data, &payload).unwrap());
        rows.push(Row {
            component: "framed write (coalesced)".into(),
            payload: fmt_bytes(payload.len() as u64),
            m,
            throughput: format!("{:.0} MB/s", payload.len() as f64 / m.median_secs / 1e6),
        });
    }

    // --- 3. block execution: GEMM core vs retained naive kernels ----------
    // Synthetic workloads (no artifacts needed) sized like mid-chain
    // blocks; naive and GEMM run on identical tensors in the same
    // process, so the speedup is measured, not remembered. The headline
    // comparison pins the GEMM side to ONE worker — the naive baseline is
    // inherently single-threaded, and the JSON trajectory must not shift
    // with the CI runner's core count; an extra row shows the env-thread
    // scaling on top.
    let mut rng = Rng::new(7);
    let mut scratch = Scratch::with_threads(1);
    let mut scratch_par = Scratch::new();
    let slow_timer = BenchTimer::new(2, 11);

    let x = rand_tensor(&mut rng, &[1, 28, 28, 32]);
    let w = rand_tensor(&mut rng, &[3, 3, 32, 64]);
    let b = rand_tensor(&mut rng, &[64]);
    let conv_flops = 2 * 28 * 28 * (3 * 3 * 32) * 64;
    let m_naive = slow_timer.measure(|| {
        std::hint::black_box(naive::conv2d(&x, &w, &b, 1, &Pad::Same, true).unwrap());
    });
    rows.push(Row {
        component: "block-exec conv3x3 (naive)".into(),
        payload: "1×28×28×32→64".into(),
        m: m_naive,
        throughput: gflops(conv_flops, &m_naive),
    });
    let m_gemm = slow_timer.measure(|| {
        let t = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut scratch).unwrap();
        scratch.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: "block-exec conv3x3 (gemm, 1 worker)".into(),
        payload: "1×28×28×32→64".into(),
        m: m_gemm,
        throughput: gflops(conv_flops, &m_gemm),
    });
    let block_exec_speedup = m_naive.median_secs / m_gemm.median_secs;
    let m_par = slow_timer.measure(|| {
        let t = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut scratch_par).unwrap();
        scratch_par.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: format!(
            "block-exec conv3x3 (gemm, {} workers)",
            serdab::runtime::scratch::env_threads()
        ),
        payload: "1×28×28×32→64".into(),
        m: m_par,
        throughput: gflops(conv_flops, &m_par),
    });

    // --- 3b. resident pool: pooled workers vs the 1-worker GEMM row -------
    // Same conv, same tensors, dispatched to the resident worker pool at
    // explicit pool sizes. Parity is checked in-run across {1, 2, 4}
    // workers (the chunk split fixes every element's accumulation order,
    // so the bytes must match exactly); check_bench.sh's compute-pool
    // lane fails the parity anywhere and enforces the ≥2× speedup floor
    // only when the producing machine has ≥ 4 cores to scale across.
    let pool_workers = 4usize;
    let conv_ref_bytes = {
        let t = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut scratch).unwrap();
        let bytes = t.to_le_bytes();
        scratch.give(t);
        bytes
    };
    let mut scratch_p2 = Scratch::with_threads(2);
    let mut scratch_p4 = Scratch::with_threads(pool_workers);
    let mut pool_parity = true;
    for sc in [&mut scratch_p2, &mut scratch_p4] {
        let t = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, sc).unwrap();
        pool_parity &= t.to_le_bytes() == conv_ref_bytes;
        sc.give(t);
    }
    let m_pool = slow_timer.measure(|| {
        let t = ops::conv2d_scratch(&x, &w, &b, 1, &Pad::Same, true, &mut scratch_p4).unwrap();
        scratch_p4.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: format!("block-exec conv3x3 (pooled, {pool_workers} workers)"),
        payload: "1×28×28×32→64".into(),
        m: m_pool,
        throughput: gflops(conv_flops, &m_pool),
    });
    let pool_speedup = m_gemm.median_secs / m_pool.median_secs;
    println!(
        "compute pool: {pool_speedup:.2}× at {pool_workers} pooled workers \
         vs 1 (parity={pool_parity})"
    );

    // --- 3c. packed-B weight panels vs the pack-free GEMM path ------------
    // The same conv through a prepacked (NR-tiled, cache-aligned) weight
    // panel from the process-wide digest cache — what every deployed
    // block uses after `load_block`. Bitwise parity is part of the lane.
    let conv_pb = gemm::pack_cache().get_or_pack(3 * 3 * 32, 64, &w.data);
    let t = ops::conv2d_packed_scratch(
        &x, &w, &b, 1, &Pad::Same, true, Some(conv_pb.as_ref()), &mut scratch,
    )
    .unwrap();
    let mut packed_parity = t.to_le_bytes() == conv_ref_bytes;
    scratch.give(t);
    let m_packed_conv = slow_timer.measure(|| {
        let t = ops::conv2d_packed_scratch(
            &x, &w, &b, 1, &Pad::Same, true, Some(conv_pb.as_ref()), &mut scratch,
        )
        .unwrap();
        scratch.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: "block-exec conv3x3 (packed-B, 1 worker)".into(),
        payload: "1×28×28×32→64".into(),
        m: m_packed_conv,
        throughput: gflops(conv_flops, &m_packed_conv),
    });

    let xd = rand_tensor(&mut rng, &[1, 4096]);
    let wd = rand_tensor(&mut rng, &[4096, 512]);
    let bd = rand_tensor(&mut rng, &[512]);
    let dense_flops = 2 * 4096 * 512;
    let m_dn = slow_timer.measure(|| {
        std::hint::black_box(naive::dense(&xd, &wd, &bd, true).unwrap());
    });
    rows.push(Row {
        component: "block-exec dense (naive)".into(),
        payload: "4096→512".into(),
        m: m_dn,
        throughput: gflops(dense_flops, &m_dn),
    });
    let m_dg = slow_timer.measure(|| {
        let t = ops::dense_scratch(&xd, &wd, &bd, true, &mut scratch).unwrap();
        scratch.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: "block-exec dense (gemm, 1 worker)".into(),
        payload: "4096→512".into(),
        m: m_dg,
        throughput: gflops(dense_flops, &m_dg),
    });
    // packed-B dense: the batch-1 GEMV walks the same panels column-first
    let dense_ref_bytes = {
        let t = ops::dense_scratch(&xd, &wd, &bd, true, &mut scratch).unwrap();
        let bytes = t.to_le_bytes();
        scratch.give(t);
        bytes
    };
    let dense_pb = gemm::pack_cache().get_or_pack(4096, 512, &wd.data);
    let t = ops::dense_packed_scratch(&xd, &wd, &bd, true, Some(dense_pb.as_ref()), &mut scratch)
        .unwrap();
    packed_parity &= t.to_le_bytes() == dense_ref_bytes;
    scratch.give(t);
    let m_packed_dense = slow_timer.measure(|| {
        let t = ops::dense_packed_scratch(
            &xd, &wd, &bd, true, Some(dense_pb.as_ref()), &mut scratch,
        )
        .unwrap();
        scratch.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: "block-exec dense (packed-B, 1 worker)".into(),
        payload: "4096→512".into(),
        m: m_packed_dense,
        throughput: gflops(dense_flops, &m_packed_dense),
    });
    println!(
        "packed-B: conv {:.2}× dense {:.2}× vs pack-free (parity={packed_parity})",
        m_gemm.median_secs / m_packed_conv.median_secs,
        m_dg.median_secs / m_packed_dense.median_secs,
    );

    let xw = rand_tensor(&mut rng, &[1, 56, 56, 64]);
    let ww = rand_tensor(&mut rng, &[3, 3, 64]);
    let bw = rand_tensor(&mut rng, &[64]);
    let dw_flops = 2 * 56 * 56 * 9 * 64;
    let m_wn = slow_timer.measure(|| {
        std::hint::black_box(naive::dwconv2d(&xw, &ww, &bw, 1, &Pad::Same, true).unwrap());
    });
    rows.push(Row {
        component: "block-exec dwconv3x3 (naive)".into(),
        payload: "1×56×56×64".into(),
        m: m_wn,
        throughput: gflops(dw_flops, &m_wn),
    });
    let m_wg = slow_timer.measure(|| {
        let t = ops::dwconv2d_scratch(&xw, &ww, &bw, 1, &Pad::Same, true, &mut scratch).unwrap();
        scratch.give(std::hint::black_box(t));
    });
    rows.push(Row {
        component: "block-exec dwconv3x3 (gemm-core, 1 worker)".into(),
        payload: "1×56×56×64".into(),
        m: m_wg,
        throughput: gflops(dw_flops, &m_wg),
    });

    // --- 4. tensor bridge + real artifact blocks (when present) -----------
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        let man = load_manifest(&dir)?;
        let backend = default_backend()?;
        let info = man.model("squeezenet")?;
        let chain = ChainExecutor::load(backend.as_ref(), &man, "squeezenet")?;
        let input =
            Tensor::from_bin_file(&man.path(&info.golden_input), man.input_shape.clone())?;

        let shape = input.shape.clone();
        let m = timer.measure(|| {
            // full round-trip: serialize (every sealed hop does this) and
            // deserialize (every opened record does)
            let wire = input.to_le_bytes();
            std::hint::black_box(Tensor::from_le_bytes(&wire, shape.clone()).unwrap())
        });
        rows.push(Row {
            component: "tensor→wire→tensor".into(),
            payload: fmt_bytes(input.byte_len() as u64),
            m,
            throughput: format!("{:.0} MB/s", 2.0 * input.byte_len() as f64 / m.median_secs / 1e6),
        });

        let b0 = &chain.blocks[0];
        let m = timer.measure(|| {
            let t = b0.run_scratch(&input, &mut scratch).unwrap();
            scratch.give(std::hint::black_box(t));
        });
        rows.push(Row {
            component: format!("block run [{}]", b0.name),
            payload: fmt_bytes(input.byte_len() as u64),
            m,
            throughput: String::new(),
        });

        let slow = BenchTimer::new(1, 5);
        let m = slow.measure(|| {
            let t = chain.run_scratch(&input, &mut scratch).unwrap();
            scratch.give(std::hint::black_box(t));
        });
        rows.push(Row {
            component: "full chain (10 blocks)".into(),
            payload: fmt_bytes(input.byte_len() as u64),
            m,
            throughput: String::new(),
        });
    } else {
        eprintln!("(artifacts missing — artifact-backed rows skipped)");
    }

    let mut table = Table::new(&["component", "payload", "median", "throughput"]);
    for r in &rows {
        table.row(vec![
            r.component.clone(),
            r.payload.clone(),
            format!("{}", r.m),
            r.throughput.clone(),
        ]);
    }
    println!("{}", table.render());
    println!("\nblock-exec speedup (gemm vs naive conv3x3): {block_exec_speedup:.2}×");

    if json_mode {
        // machine class stamp: scripts/check_bench.sh only enforces the
        // crypto speedup floor when the recorded class matches the
        // checking host (`$(uname -m)-$(nproc)cpu`) or STRICT=1
        let ncpu = std::thread::available_parallelism().map_or(1, |n| n.get());
        let machine = format!("{}-{ncpu}cpu", std::env::consts::ARCH);
        let json = obj(vec![
            ("bench", s("hotpath_microbench")),
            ("generator", s("cargo bench --bench hotpath_microbench -- --json")),
            ("machine", s(&machine)),
            ("threads", num(serdab::runtime::scratch::env_threads() as f64)),
            (
                "rows",
                arr(rows
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("component", s(r.component.clone())),
                            ("payload", s(r.payload.clone())),
                            ("median_ns", num((r.m.median_secs * 1e9).round())),
                            ("throughput", s(r.throughput.clone())),
                        ])
                    })
                    .collect()),
            ),
            (
                "block_exec",
                obj(vec![
                    ("naive_ns", num((m_naive.median_secs * 1e9).round())),
                    ("gemm_ns", num((m_gemm.median_secs * 1e9).round())),
                    ("speedup", Json::Num(block_exec_speedup)),
                ]),
            ),
            (
                "sealed_hop",
                obj(vec![
                    ("aesni", Json::Bool(aesni)),
                    ("parity", Json::Bool(hop_parity)),
                    ("rows", arr(hop_rows)),
                ]),
            ),
            (
                "compute_pool",
                obj(vec![
                    // core count travels with the artifact: the speedup
                    // floor only binds where ≥ 4 cores exist to scale on
                    ("cores", num(ncpu as f64)),
                    ("workers", num(pool_workers as f64)),
                    ("parity", Json::Bool(pool_parity)),
                    ("gemm_1w_ns", num((m_gemm.median_secs * 1e9).round())),
                    ("pooled_ns", num((m_pool.median_secs * 1e9).round())),
                    ("speedup", Json::Num(m_gemm.median_secs / m_pool.median_secs)),
                ]),
            ),
            (
                "packed_b",
                obj(vec![
                    ("parity", Json::Bool(packed_parity)),
                    (
                        "rows",
                        arr(vec![
                            obj(vec![
                                ("component", s("conv3x3")),
                                ("unpacked_ns", num((m_gemm.median_secs * 1e9).round())),
                                ("packed_ns", num((m_packed_conv.median_secs * 1e9).round())),
                                (
                                    "speedup",
                                    Json::Num(m_gemm.median_secs / m_packed_conv.median_secs),
                                ),
                            ]),
                            obj(vec![
                                ("component", s("dense")),
                                ("unpacked_ns", num((m_dg.median_secs * 1e9).round())),
                                ("packed_ns", num((m_packed_dense.median_secs * 1e9).round())),
                                (
                                    "speedup",
                                    Json::Num(m_dg.median_secs / m_packed_dense.median_secs),
                                ),
                            ]),
                        ]),
                    ),
                ]),
            ),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .join("BENCH_hotpath.json");
        std::fs::write(&path, json.to_string_pretty() + "\n")?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
