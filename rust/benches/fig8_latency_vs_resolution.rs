//! Fig. 8 — relationship between the percentage of inference time spent
//! and the resolution of the intermediate output, per model.
//!
//! Paper shape: monotone — deeper ⇒ more cumulative time, lower
//! resolution; GoogLeNet/SqueezeNet need ~80% of inference time to reach
//! an output ≤ 20×20 px while AlexNet/ResNet get there in < 50%.

use serdab::figures::{dump_json, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::{DELTA_RESOLUTION, MODEL_NAMES};
use serdab::profiler::calibrate::tee_block_secs_with_paging;
use serdab::profiler::calibrated_profile;
use serdab::util::json::{arr, num, obj, s};

fn main() -> anyhow::Result<()> {
    let man = load_manifest(default_artifacts_dir())?;
    println!("# Fig. 8 — % of inference time vs resolution of intermediate output\n");

    let mut json_models = Vec::new();
    for name in MODEL_NAMES {
        let model = man.model(name)?;
        let profile = calibrated_profile(model);
        let secs = tee_block_secs_with_paging(&profile);
        let total: f64 = secs.iter().sum();

        let mut table = Table::new(&["block", "out resolution", "cum. time %"]);
        let mut series = Vec::new();
        let mut cum = 0.0;
        let mut frac_at_delta = None;
        for (b, &t) in model.blocks.iter().zip(&secs) {
            cum += t;
            let pct = 100.0 * cum / total;
            table.row(vec![b.name.clone(), format!("{}x{}", b.out_res, b.out_res), format!("{pct:.1}%")]);
            series.push(obj(vec![
                ("block", s(b.name.clone())),
                ("out_res", num(b.out_res as f64)),
                ("cum_time_pct", num(pct)),
            ]));
            if frac_at_delta.is_none() && b.out_res <= DELTA_RESOLUTION {
                frac_at_delta = Some(pct);
            }
        }
        let at_delta = frac_at_delta.expect("model must cross δ");
        println!("## {name} — reaches ≤{DELTA_RESOLUTION}x{DELTA_RESOLUTION} at {at_delta:.0}% of inference time\n");
        println!("{}\n", table.render());
        json_models.push(obj(vec![
            ("model", s(name)),
            ("pct_at_delta", num(at_delta)),
            ("series", arr(series)),
        ]));
    }

    println!("paper: googlenet/squeezenet ≈80%, mobilenet ≈70%, alexnet/resnet <50%");
    let path = dump_json("fig8", &obj(vec![("models", arr(json_models))]))?;
    println!("json: {}", path.display());
    Ok(())
}
