//! Fig. 5 — motivating comparison: one frame vs a 1000-frame stream under
//! three deployments of GoogLeNet:
//!   (1) all layers in TEE₁,
//!   (2) partitioned across TEE₁ and E₂ (untrusted CPU, privacy-constrained
//!       cut ⇒ most layers stay in the enclave),
//!   (3) partitioned across TEE₁ and TEE₂ (cut anywhere ⇒ balanced).
//!
//! Paper shape: case (2) wins for a single frame (fastest processor gets
//! the offloadable tail) but case (3) wins for the stream, because pipeline
//! parallelism makes completion time track the slowest *stage* and two
//! enclaves split the work evenly — the insight behind the whole system.

use serdab::figures::{dump_json, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::tree::enumerate_paths;
use serdab::profiler::calibrated_profile;
use serdab::sim::{simulate, SimConfig};
use serdab::util::json::{num, obj, s};

fn main() -> anyhow::Result<()> {
    let man = load_manifest(default_artifacts_dir())?;
    let model = man.model("googlenet")?;
    let profile = calibrated_profile(model);
    let cm = CostModel::paper(&profile);
    let m = profile.m;
    let tee1 = cm.topology().require("TEE1").unwrap();
    let e2 = cm.topology().require("E2").unwrap();

    // case 1: all in TEE1
    let case1 = plan(Strategy::OneTee, &cm, 1000);

    // case 2: TEE1 + untrusted E2 CPU (privacy-constrained cut)
    let case2 = {
        let mut best: Option<serdab::placement::strategies::Plan> = None;
        for p in enumerate_paths(&[tee1, e2], m) {
            if !p.satisfies_privacy(cm.topology(), &profile.in_res, serdab::model::DELTA_RESOLUTION)
            {
                continue;
            }
            let cost = cm.cost(&p);
            if best.as_ref().map_or(true, |b| cost.chunk_secs(1000) < b.cost.chunk_secs(1000)) {
                best = Some(serdab::placement::strategies::Plan {
                    strategy: Strategy::Proposed,
                    placement: p,
                    cost,
                    examined: 0,
                });
            }
        }
        best.unwrap()
    };

    // case 3: TEE1 + TEE2
    let case3 = plan(Strategy::TwoTees, &cm, 1000);

    let mut table = Table::new(&["case", "placement", "1 frame", "1000 frames (DES)", "period"]);
    let mut json_rows = Vec::new();
    for (label, p) in [
        ("all in TEE1", &case1),
        ("TEE1 + E2 (untrusted)", &case2),
        ("TEE1 + TEE2", &case3),
    ] {
        let des = simulate(&cm, &p.placement, &SimConfig { frames: 1000, ..Default::default() });
        table.row(vec![
            label.into(),
            p.placement.describe(cm.topology()),
            format!("{:.3}s", p.cost.single_secs),
            format!("{:.1}s", des.completion_secs),
            format!("{:.3}s", p.cost.period_secs),
        ]);
        json_rows.push(obj(vec![
            ("case", s(label)),
            ("placement", s(p.placement.describe(cm.topology()))),
            ("single_secs", num(p.cost.single_secs)),
            ("stream_secs", num(des.completion_secs)),
            ("period_secs", num(p.cost.period_secs)),
        ]));
    }

    println!("# Fig. 5 — GoogLeNet, single frame vs 1000-frame stream\n");
    println!("{}", table.render());

    let one_frame_winner = if case2.cost.single_secs < case3.cost.single_secs {
        "TEE1+E2"
    } else {
        "TEE1+TEE2"
    };
    let stream2 = simulate(&cm, &case2.placement, &SimConfig { frames: 1000, ..Default::default() });
    let stream3 = simulate(&cm, &case3.placement, &SimConfig { frames: 1000, ..Default::default() });
    let stream_winner = if stream2.completion_secs < stream3.completion_secs {
        "TEE1+E2"
    } else {
        "TEE1+TEE2"
    };
    println!("\nsingle-frame winner: {one_frame_winner} (paper: TEE1+E2)");
    println!("stream winner:       {stream_winner} (paper: TEE1+TEE2 — pipeline parallelism)");
    assert_eq!(stream_winner, "TEE1+TEE2", "paper's headline insight must hold");

    let path = dump_json(
        "fig5",
        &obj(vec![
            ("model", s("googlenet")),
            ("cases", serdab::util::json::arr(json_rows)),
            ("single_frame_winner", s(one_frame_winner)),
            ("stream_winner", s(stream_winner)),
        ]),
    )?;
    println!("json: {}", path.display());
    Ok(())
}
