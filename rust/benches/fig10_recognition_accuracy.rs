//! Fig. 10 — accuracy of object recognition by (simulated) subjects at
//! different resolution ranges.
//!
//! Paper shape: ~100% accuracy above 110×110; slight degradation in the
//! 26–32 px range; drastic drop at 12–18 px ⇒ δ = 20×20 is the sweet spot.

use serdab::figures::{dump_json, Table};
use serdab::study::accuracy_by_resolution;
use serdab::util::json::{arr, num, obj};

fn main() -> anyhow::Result<()> {
    // the paper's Fig. 10 resolution bands (representative points per band)
    let bands: [(usize, &str); 6] = [
        (128, "≥110x110"),
        (64, "55x55-64x64"),
        (32, "26x26-32x32"),
        (18, "12x12-18x18"),
        (8, "6x6-8x8"),
        (4, "≤4x4"),
    ];
    let resolutions: Vec<usize> = bands.iter().map(|b| b.0).collect();
    println!("# Fig. 10 — recognition accuracy vs resolution (simulated subjects)\n");

    let acc = accuracy_by_resolution(&resolutions, 10, 2026);
    let mut table = Table::new(&["resolution band", "accuracy"]);
    let mut json_rows = Vec::new();
    for ((res, label), (_, a)) in bands.iter().zip(&acc) {
        table.row(vec![label.to_string(), format!("{:.0}%", a * 100.0)]);
        json_rows.push(obj(vec![
            ("resolution", num(*res as f64)),
            ("accuracy", num(*a)),
        ]));
    }
    println!("{}", table.render());

    let hi = acc[0].1;
    let mid = acc[2].1;
    let lo = acc[3].1;
    println!("\npaper shape: ~100% above 110px, slight drop at 26-32px, drastic drop at 12-18px");
    assert!(hi > 0.85, "high-res accuracy {hi}");
    assert!(mid < hi + 1e-9 && mid > lo, "band ordering violated");
    assert!(lo < hi - 0.3, "no drastic drop: hi={hi} lo={lo}");
    println!("measured: hi={:.2} mid={:.2} lo={:.2} — knee confirmed below ~20px", hi, mid, lo);

    let path = dump_json("fig10", &obj(vec![("bands", arr(json_rows))]))?;
    println!("json: {}", path.display());
    Ok(())
}
