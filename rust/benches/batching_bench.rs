//! Micro-batching density bench (DESIGN.md §16): serving throughput and
//! tail latency of the live pipeline engine as the stage-intake batch
//! size grows.
//!
//! One real pipeline stage (conv block on the reference GEMM core, no
//! artifacts needed) is saturated with frames at batch sizes 1, 2, 4, 8;
//! each configuration reports completed frames/sec and p99 end-to-end
//! latency. The same run also proves the determinism contract the
//! batched path promises: `process_batch` over N frames must be
//! *bitwise* identical to N sequential `process` calls.
//!
//! `--json` writes `BENCH_batching.json` at the repo root — the CI
//! perf-trend lane (`scripts/check_bench.sh`) gates on it: parity must
//! hold and fps at B=8 must stay ≥ 1.2× the batch-1 baseline.

use anyhow::Result;
use serdab::dataflow::Operator;
use serdab::figures::Table;
use serdab::runtime::backend::reference::ops;
use serdab::runtime::backend::reference::zoo::Pad;
use serdab::runtime::pipeline::{
    FrameIn, Pipeline, PipelineConfig, PipelineRunReport, StageSpec, WorkerKind,
};
use serdab::runtime::{Scratch, Tensor};
use serdab::util::json::{arr, num, obj, s, Json};
use serdab::util::rng::Rng;

/// Frame geometry: small enough that per-invocation costs (worker-pool
/// coordination, packing, loop bookkeeping) are a visible share of the
/// per-frame time — exactly the regime micro-batching exists to amortize.
const IN_SHAPE: [usize; 4] = [1, 8, 8, 8];
const KERNEL: [usize; 4] = [3, 3, 8, 16];
const FRAMES: usize = 4096;
const BATCHES: [usize; 4] = [1, 2, 4, 8];

/// The benched stage: one conv block on the reference GEMM core. Its
/// batched path stacks the frames along dim 0 into a single GEMM — the
/// same folding `NnService::process_batch` does, minus the crypto.
struct ConvStage {
    w: Tensor,
    b: Tensor,
    scratch: Scratch,
}

impl ConvStage {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        ConvStage {
            w: rand_tensor(&mut rng, &KERNEL),
            b: rand_tensor(&mut rng, &[KERNEL[3]]),
            scratch: Scratch::new(),
        }
    }

    /// Run `n` stacked frames (raw little-endian f32 bytes) through the
    /// conv and return the stacked output bytes.
    fn run_stacked(&mut self, n: usize, bytes: &[u8]) -> Result<Vec<u8>> {
        let mut shape = IN_SHAPE.to_vec();
        shape[0] = n;
        let x = Tensor::from_le_bytes(bytes, shape)?;
        let y = ops::conv2d_scratch(&x, &self.w, &self.b, 1, &Pad::Same, true, &mut self.scratch)?;
        let out = y.to_le_bytes();
        self.scratch.give(y);
        Ok(out)
    }
}

impl Operator for ConvStage {
    fn name(&self) -> String {
        "bench-conv".into()
    }

    fn process(&mut self, sealed: &[u8]) -> Result<Vec<u8>> {
        self.run_stacked(1, sealed)
    }

    fn process_batch(&mut self, sealed: &[Vec<u8>], outs: &mut Vec<Vec<u8>>) -> Result<()> {
        if sealed.len() == 1 {
            outs.push(self.process(&sealed[0])?);
            return Ok(());
        }
        let mut stacked = Vec::with_capacity(sealed.iter().map(|p| p.len()).sum());
        for p in sealed {
            stacked.extend_from_slice(p);
        }
        let out = self.run_stacked(sealed.len(), &stacked)?;
        let per = out.len() / sealed.len();
        for i in 0..sealed.len() {
            outs.push(out[i * per..(i + 1) * per].to_vec());
        }
        Ok(())
    }
}

fn rand_tensor(rng: &mut Rng, shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    Tensor::new(shape.to_vec(), data).unwrap()
}

fn rand_payload(rng: &mut Rng) -> Vec<u8> {
    rand_tensor(rng, &IN_SHAPE).to_le_bytes()
}

/// Saturate one pipeline stage with `frames` identical-shape frames at
/// the given intake batch size and return the engine's run report.
fn run_at(batch: usize, frames: usize) -> Result<PipelineRunReport> {
    let cfg = PipelineConfig {
        queue_cap: 64,
        batch,
        batch_wait_us: 5_000,
        ..PipelineConfig::default()
    };
    let mut p = Pipeline::new(cfg);
    p.add_stage(StageSpec::new("bench-conv", WorkerKind::Stage, || {
        Ok(Box::new(ConvStage::new(7)))
    }));
    let mut rng = Rng::new(11);
    let payload = rand_payload(&mut rng);
    let feed = (0..frames).map(move |_| FrameIn { stream: 0, payload: payload.clone() });
    p.run(feed, |_| {})
}

/// Bitwise batch-vs-sequential parity on distinct random frames: the
/// determinism contract the JSON gate refuses to trade for throughput.
fn parity_holds() -> Result<bool> {
    let mut rng = Rng::new(23);
    let frames: Vec<Vec<u8>> = (0..8).map(|_| rand_payload(&mut rng)).collect();
    let mut seq = ConvStage::new(7);
    let mut bat = ConvStage::new(7);
    for take in [2usize, 3, 8] {
        let slice = &frames[..take];
        let expect: Vec<Vec<u8>> =
            slice.iter().map(|f| seq.process(f)).collect::<Result<_>>()?;
        let mut got = Vec::new();
        bat.process_batch(slice, &mut got)?;
        if got != expect {
            return Ok(false);
        }
    }
    Ok(true)
}

fn main() -> Result<()> {
    let json_mode = std::env::args().any(|a| a == "--json");
    println!("# micro-batching density bench\n");

    let parity = parity_holds()?;
    println!(
        "batched-vs-sequential parity (B ∈ {{2,3,8}}): {}",
        if parity { "bitwise identical" } else { "MISMATCH" }
    );

    // warm-up: page in the code paths and the worker pool once
    run_at(1, 256)?;

    let mut rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    for &b in &BATCHES {
        let rep = run_at(b, FRAMES)?;
        anyhow::ensure!(rep.frames == FRAMES as u64, "lost frames at batch {b}");
        rows.push((b, rep.throughput(), rep.p99_latency() * 1e3, rep.mean_latency() * 1e3));
    }

    let mut table = Table::new(&["batch", "frames/sec", "p99 latency", "mean latency"]);
    for &(b, fps, p99, mean) in &rows {
        table.row(vec![
            format!("{b}"),
            format!("{fps:.0}"),
            format!("{p99:.3} ms"),
            format!("{mean:.3} ms"),
        ]);
    }
    println!("{}", table.render());

    let fps1 = rows[0].1;
    let fps8 = rows.last().unwrap().1;
    let speedup = fps8 / fps1;
    println!("serving-density speedup (B=8 vs B=1): {speedup:.2}×");

    if json_mode {
        // machine class stamp: scripts/check_bench.sh only enforces the
        // speedup floor when the recorded class matches the checking host
        // (`$(uname -m)-$(nproc)cpu`), so a laptop artifact never fails CI
        let ncpu = std::thread::available_parallelism().map_or(1, |n| n.get());
        let machine = format!("{}-{ncpu}cpu", std::env::consts::ARCH);
        let json = obj(vec![
            ("bench", s("batching_bench")),
            ("generator", s("cargo bench --bench batching_bench -- --json")),
            ("machine", s(&machine)),
            ("threads", num(serdab::runtime::scratch::env_threads() as f64)),
            ("frames", num(FRAMES as f64)),
            ("parity", Json::Bool(parity)),
            (
                "rows",
                arr(rows
                    .iter()
                    .map(|&(b, fps, p99, mean)| {
                        obj(vec![
                            ("batch", num(b as f64)),
                            ("fps", Json::Num(fps)),
                            ("p99_ms", Json::Num(p99)),
                            ("mean_ms", Json::Num(mean)),
                        ])
                    })
                    .collect()),
            ),
            ("speedup_b8", Json::Num(speedup)),
        ]);
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("rust/ has a parent")
            .join("BENCH_batching.json");
        std::fs::write(&path, json.to_string_pretty() + "\n")?;
        println!("wrote {}", path.display());
    }
    Ok(())
}
