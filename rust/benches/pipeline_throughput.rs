//! Pipeline throughput — the executed demonstration of the paper's core
//! claim: streaming a chunk through a multi-stage pipeline completes it
//! faster than the sequential single-enclave baseline, because stages
//! overlap on different frames (Fig. 6 / Fig. 12 mechanism, but measured
//! on real worker threads instead of the cost model).
//!
//! Two modes:
//!  * with artifacts: squeezenet on the reference backend through the full
//!    attested `Deployment` (real NN compute, real AES-GCM, real framing);
//!  * without artifacts: the synthetic cost-calibrated pipeline (the same
//!    engine the DES cross-validation uses), so the bench always runs.
//!
//! Either way the bench asserts pipelined < sequential before printing.

use serdab::coordinator::{Deployment, ResourceManager};
use serdab::figures::{dump_json, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::placement::{Placement, Stage};
use serdab::profiler::{calibrated_profile, ModelProfile};
use serdab::runtime::pipeline::{FrameIn, Pipeline, PipelineConfig};
use serdab::sim::{simulate, SimConfig};
use serdab::util::json::{arr, num, obj, s};
use serdab::video::{SceneKind, VideoSource};

const FRAMES: u64 = 30;

fn main() -> anyhow::Result<()> {
    println!("# pipeline_throughput — executed multi-stage vs sequential 1-stage\n");
    match load_manifest(default_artifacts_dir()) {
        Ok(man) => reference_backend_bench(&man),
        Err(_) => {
            println!("(artifacts not found — synthetic cost-calibrated pipeline)\n");
            synthetic_bench()
        }
    }
}

/// Synthetic mode: workers sleep what the cost model charges. Also prints
/// the DES prediction next to each executed number — the two agreeing is
/// the same check `tests/pipeline_vs_sim.rs` enforces.
fn synthetic_bench() -> anyhow::Result<()> {
    // the same fixture tests/pipeline_vs_sim.rs validates against the DES
    let prof = ModelProfile::millis_demo();
    let cm = CostModel::paper(&prof);

    let mut table = Table::new(&[
        "strategy",
        "placement",
        "executed chunk",
        "DES chunk",
        "throughput",
        "speedup",
    ]);
    let mut rows = Vec::new();
    let mut baseline = 0.0f64;
    let mut results = Vec::new();
    for strat in [Strategy::OneTee, Strategy::TwoTees, Strategy::Proposed] {
        let p = plan(strat, &cm, FRAMES);
        let cost = cm.cost(&p.placement);
        let des = simulate(&cm, &p.placement, &SimConfig { frames: FRAMES, ..Default::default() });
        let pipe =
            Pipeline::synthetic(cm.topology(), &p.placement, &cost, PipelineConfig::default());
        let feed = (0..FRAMES).map(|_| FrameIn { stream: 0, payload: vec![0u8; 64] });
        let rep = pipe.run(feed, |_| {})?;
        if strat == Strategy::OneTee {
            baseline = rep.completion_secs;
        }
        let speedup = baseline / rep.completion_secs;
        table.row(vec![
            strat.name().to_string(),
            p.placement.describe(cm.topology()),
            format!("{:.3}s", rep.completion_secs),
            format!("{:.3}s", des.completion_secs),
            format!("{:.1} fps", rep.throughput()),
            format!("{speedup:.2}x"),
        ]);
        rows.push(obj(vec![
            ("strategy", s(strat.name())),
            ("placement", s(p.placement.describe(cm.topology()))),
            ("executed_chunk_secs", num(rep.completion_secs)),
            ("des_chunk_secs", num(des.completion_secs)),
            ("speedup", num(speedup)),
        ]));
        results.push((strat, rep.completion_secs));
    }
    println!("{}", table.render());

    let one = results[0].1;
    for (strat, t) in &results[1..] {
        assert!(
            *t < one,
            "{strat:?} pipeline ({t:.3}s) not faster than sequential 1-TEE ({one:.3}s)"
        );
    }
    println!("\npipelined multi-stage beats the sequential baseline ✓");
    let path = dump_json(
        "pipeline_throughput",
        &obj(vec![("frames", num(FRAMES as f64)), ("mode", s("synthetic")), ("rows", arr(rows))]),
    )?;
    println!("json: {}", path.display());
    Ok(())
}

/// Artifact mode: real NN compute on the reference backend through the
/// attested deployment (camera sealing, enclave open/compute/seal, WAN
/// links on cross-host edges).
fn reference_backend_bench(man: &serdab::model::Manifest) -> anyhow::Result<()> {
    let model = "squeezenet";
    let info = man.model(model)?;
    let m = info.m();
    let rm = ResourceManager::paper_testbed();
    let profile = calibrated_profile(info);
    let cm = CostModel::paper(&profile);

    let frames = || {
        let mut cam = VideoSource::new(SceneKind::Street, 11);
        (0..FRAMES).map(move |_| cam.next_frame())
    };

    // sequential baseline: everything in one enclave
    let tee1 = rm.topology().require("TEE1").unwrap();
    let tee2 = rm.topology().require("TEE2").unwrap();
    let one = Placement::single(tee1, m);
    let dep1 = Deployment::deploy(man, &rm, model, &one, Some(1e9), 4)?;
    let r1 = dep1.run_stream(frames())?;

    // pipelined: the solver's 2-TEE split
    let two_plan = plan(Strategy::TwoTees, &cm, FRAMES);
    let cut = two_plan.placement.stages[0].range.end;
    let two = Placement {
        stages: vec![
            Stage { resource: tee1, range: 0..cut },
            Stage { resource: tee2, range: cut..m },
        ],
    };
    let dep2 = Deployment::deploy(man, &rm, model, &two, Some(1e9), 4)?;
    let r2 = dep2.run_stream(frames())?;

    let mut table = Table::new(&["placement", "chunk", "throughput", "p99 latency", "speedup"]);
    table.row(vec![
        one.describe(rm.topology()),
        format!("{:.3}s", r1.total_secs),
        format!("{:.1} fps", r1.throughput_fps),
        format!("{:.1}ms", r1.p99_latency_secs * 1e3),
        "1.00x".into(),
    ]);
    table.row(vec![
        two.describe(rm.topology()),
        format!("{:.3}s", r2.total_secs),
        format!("{:.1} fps", r2.throughput_fps),
        format!("{:.1}ms", r2.p99_latency_secs * 1e3),
        format!("{:.2}x", r1.total_secs / r2.total_secs),
    ]);
    println!("{}", table.render());

    println!("\nper-stage occupancy (pipelined run):");
    for w in &r2.workers {
        println!(
            "  {:<16} frames={} occupancy={:.2} mean-queue-wait={:.2}ms",
            w.label,
            w.frames,
            w.occupancy(r2.total_secs),
            w.mean_queue_wait() * 1e3
        );
    }

    assert!(
        r2.total_secs < r1.total_secs,
        "pipelined 2-stage ({:.3}s) not faster than sequential 1-stage ({:.3}s)",
        r2.total_secs,
        r1.total_secs
    );
    println!("\npipelined multi-stage beats the sequential baseline on the reference backend ✓");
    let path = dump_json(
        "pipeline_throughput",
        &obj(vec![
            ("frames", num(FRAMES as f64)),
            ("mode", s("reference-backend")),
            ("sequential_secs", num(r1.total_secs)),
            ("pipelined_secs", num(r2.total_secs)),
            ("speedup", num(r1.total_secs / r2.total_secs)),
        ]),
    )?;
    println!("json: {}", path.display());
    Ok(())
}
