//! Fig. 12 — the paper's headline result: speedup of five partitioning
//! strategies over the 1-TEE baseline for a 10 800-frame stream, per model.
//!
//! Paper shape to reproduce:
//!   * GoogLeNet / MobileNet / SqueezeNet: 2 TEEs (1.8–1.95×) beats
//!     1 TEE + GPU (1.15–1.5×) because the resolution crosses δ late;
//!   * AlexNet / ResNet: 1 TEE + GPU (2.5–3.1×) beats 2 TEEs (2.2–2.3×)
//!     because the crossing is early;
//!   * Proposed (2 TEEs + GPU) is best everywhere: 3.2–4.7×, max AlexNet;
//!   * No-pipelining collapses to the 1 TEE + GPU decision.
//!
//! Both the closed-form cost model and the discrete-event simulator score
//! every strategy; the two agreeing is part of the check.

use serdab::figures::{dump_json, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::MODEL_NAMES;
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::profiler::calibrated_profile;
use serdab::sim::{simulate, SimConfig};
use serdab::util::json::{arr, num, obj, s, Json};

const FRAMES: u64 = 10_800; // the paper's dataset: 3h of video at 1 fps

fn main() -> anyhow::Result<()> {
    let man = load_manifest(default_artifacts_dir())?;
    println!("# Fig. 12 — speedup vs 1-TEE, {FRAMES} frames, δ=20px, 30 Mbps WAN\n");

    let mut table = Table::new(&[
        "model", "1 TEE", "No pipelining", "1 TEE & 1 GPU", "2 TEEs", "Proposed",
        "proposed placement",
    ]);
    let mut json_models = Vec::new();

    for name in MODEL_NAMES {
        let model = man.model(name)?;
        let profile = calibrated_profile(model);
        let cm = CostModel::paper(&profile);

        let base_plan = plan(Strategy::OneTee, &cm, FRAMES);
        let base_des = simulate(&cm, &base_plan.placement, &SimConfig {
            frames: FRAMES,
            ..Default::default()
        })
        .completion_secs;

        let mut cells = vec![name.to_string()];
        let mut jrow = vec![("model", s(name))];
        let mut speedups = Vec::new();
        let mut proposed_desc = String::new();
        for strat in Strategy::ALL {
            let p = plan(strat, &cm, FRAMES);
            let des = simulate(&cm, &p.placement, &SimConfig {
                frames: FRAMES,
                ..Default::default()
            })
            .completion_secs;
            let model_speedup = base_plan.cost.chunk_secs(FRAMES) / p.cost.chunk_secs(FRAMES);
            let des_speedup = base_des / des;
            // closed form and DES must agree (within 2%)
            let err = (model_speedup - des_speedup).abs() / des_speedup;
            assert!(
                err < 0.02,
                "{name}/{:?}: model {model_speedup:.3} vs DES {des_speedup:.3}",
                strat
            );
            cells.push(format!("{des_speedup:.2}x"));
            speedups.push((strat.name(), des_speedup));
            if strat == Strategy::Proposed {
                proposed_desc = p.placement.describe(cm.topology());
            }
        }
        cells.push(proposed_desc.clone());
        table.row(cells);
        jrow.push((
            "speedups",
            Json::Obj(
                speedups
                    .iter()
                    .map(|(k, v)| (k.to_string(), num(*v)))
                    .collect(),
            ),
        ));
        jrow.push(("proposed_placement", s(proposed_desc)));
        json_models.push(obj(jrow));
    }

    println!("{}", table.render());
    println!("\npaper: 2TEE wins for googlenet/mobilenet/squeezenet (1.8-1.95x vs 1.15-1.5x);");
    println!("       GPU wins for alexnet/resnet (2.5-3.1x vs 2.2-2.3x); proposed 3.2-4.7x.");
    let path = dump_json("fig12", &obj(vec![("frames", num(FRAMES as f64)), ("models", arr(json_models))]))?;
    println!("json: {}", path.display());
    Ok(())
}
