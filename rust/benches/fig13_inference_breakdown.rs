//! Fig. 13 — single-frame execution-time breakdown, 1 TEE vs 2 TEEs:
//! compute in TEE₁, encrypt, transmit (30 Mbps), decrypt, compute in TEE₂.
//!
//! Paper shape: the sum of the two enclaves' compute times is *less* than
//! the whole model in one enclave for 4 of the 5 models (paging relief —
//! each enclave's resident set shrinks), most pronounced for AlexNet
//! (largest model, 243 MB) and absent for SqueezeNet (5 MB, never pages).
//! AES-128 enc+dec stays < 2.5 ms/frame (we measure our real AES-GCM);
//! transmission is 0.01–0.12 s depending on the boundary tensor.

use serdab::crypto::gcm::AesGcm;
use serdab::figures::{dump_json, BenchTimer, Table};
use serdab::model::manifest::{default_artifacts_dir, load_manifest};
use serdab::model::MODEL_NAMES;
use serdab::placement::cost::CostModel;
use serdab::placement::strategies::{plan, Strategy};
use serdab::profiler::calibrated_profile;
use serdab::util::json::{arr, num, obj, s};

/// Measure real AES-128-GCM seal+open on a tensor of `bytes`.
fn measure_crypto_secs(bytes: usize) -> f64 {
    let gcm = AesGcm::new(b"serdab-fig13-key");
    let timer = BenchTimer::new(2, 9);
    let mut buf = vec![7u8; bytes];
    let m = timer.measure(|| {
        let tag = gcm.seal(&[1u8; 12], b"fig13", &mut buf);
        gcm.open(&[1u8; 12], b"fig13", &mut buf, &tag).unwrap();
    });
    m.median_secs
}

fn main() -> anyhow::Result<()> {
    let man = load_manifest(default_artifacts_dir())?;
    println!("# Fig. 13 — per-frame breakdown: 1 TEE vs 2 TEEs\n");

    let mut table = Table::new(&[
        "model", "1 TEE total", "TEE1 part", "enc+dec (measured)", "transmit", "TEE2 part",
        "2-TEE compute sum", "paging relief",
    ]);
    let mut json_models = Vec::new();
    let mut relief_count = 0;

    for name in MODEL_NAMES {
        let model = man.model(name)?;
        let profile = calibrated_profile(model);
        let cm = CostModel::paper(&profile);

        let one = plan(Strategy::OneTee, &cm, 1).cost.single_secs;
        let two = plan(Strategy::TwoTees, &cm, 10_800);
        assert_eq!(two.placement.stages.len(), 2);
        let cut = two.placement.stages[0].range.end;
        let boundary_bytes = profile.cut_bytes[cut - 1];

        let t1 = two.cost.stage_secs[0];
        let t2 = two.cost.stage_secs[1];
        let crypto = measure_crypto_secs(boundary_bytes as usize);
        let transmit = cm.topology().transfer_secs(0, 1, boundary_bytes);
        let sum2 = t1 + t2;
        let relief = one - sum2;
        if relief > 0.0 {
            relief_count += 1;
        }

        // the paper's stated bound on AES cost is 2.5 ms/frame for *their*
        // boundary tensors (≤ ~0.5 MB); scale the bound by tensor size and
        // keep a generous ceiling — crypto must stay negligible vs compute
        assert!(
            crypto < 25e-3 && crypto < 0.05 * (t1 + t2),
            "{name}: measured AES {crypto}s is not negligible vs compute {:.2}s",
            t1 + t2
        );

        table.row(vec![
            name.into(),
            format!("{one:.2}s"),
            format!("{t1:.2}s"),
            format!("{:.2}ms", crypto * 1e3),
            format!("{transmit:.3}s"),
            format!("{t2:.2}s"),
            format!("{sum2:.2}s"),
            format!("{:+.2}s", relief),
        ]);
        json_models.push(obj(vec![
            ("model", s(name)),
            ("one_tee_secs", num(one)),
            ("tee1_secs", num(t1)),
            ("tee2_secs", num(t2)),
            ("crypto_secs_measured", num(crypto)),
            ("transmit_secs", num(transmit)),
            ("boundary_bytes", num(boundary_bytes as f64)),
            ("paging_relief_secs", num(relief)),
        ]));
    }

    println!("{}", table.render());
    println!("\nmodels with 2-TEE compute sum < 1-TEE total: {relief_count}/5 (paper: 4/5, squeezenet excepted)");
    println!("paper: enc+dec < 2.5 ms/frame; transmission 0.01–0.12 s; compute 1.1 s (squeezenet) – 7.2 s (resnet)");

    let path = dump_json(
        "fig13",
        &obj(vec![("models", arr(json_models)), ("relief_count", num(relief_count as f64))]),
    )?;
    println!("json: {}", path.display());
    Ok(())
}
