//! Fig. 11 — percentage of (simulated) subjects whose similarity ranking of
//! five degraded images matches the resolution-based ranking, per rank.
//!
//! Paper shape: disagreement about which image is *most* similar (rank 1),
//! general consensus about the least similar (ranks 4–5, where resolution
//! has fallen below ~20×20).

use serdab::figures::{dump_json, Table};
use serdab::study::simulate_ranking;
use serdab::util::json::{arr, num, obj};

fn main() -> anyhow::Result<()> {
    // the paper's example ladder (Fig. 9 shows 224→114→57→29→14-style steps)
    let ladder = [114usize, 57, 29, 20, 14];
    let subjects = 10; // the paper's subject count
    let questions = 5; // one per model, as in the survey

    println!("# Fig. 11 — ranking agreement with the resolution ranking (simulated)\n");
    // more questions for a stable estimate; the paper's 5-question survey
    // is one draw of this process
    let rep = simulate_ranking(ladder, subjects, questions * 8, 2026);

    let mut table = Table::new(&["rank (1 = most similar)", "% subjects matching resolution rank"]);
    let mut rows = Vec::new();
    for (i, &a) in rep.agreement_by_rank.iter().enumerate() {
        table.row(vec![format!("{}", i + 1), format!("{:.0}%", a * 100.0)]);
        rows.push(obj(vec![("rank", num((i + 1) as f64)), ("agreement", num(a))]));
    }
    println!("{}", table.render());

    let a = rep.agreement_by_rank;
    println!("\npaper shape: rank 1 contested; ranks 4-5 consensual");
    assert!(a[4] > a[0], "rank-5 consensus {} must exceed rank-1 {}", a[4], a[0]);
    assert!(a[4] > 0.6, "rank-5 consensus too weak: {}", a[4]);
    println!("measured: rank1={:.0}% rank5={:.0}% — consensus grows toward low resolution", a[0] * 100.0, a[4] * 100.0);

    let path = dump_json(
        "fig11",
        &obj(vec![
            ("ladder", arr(ladder.iter().map(|&r| num(r as f64)).collect())),
            ("agreement_by_rank", arr(rows)),
        ]),
    )?;
    println!("json: {}", path.display());
    Ok(())
}
