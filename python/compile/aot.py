"""AOT lowering: JAX/Pallas model zoo -> artifacts/ consumed by the Rust side.

Per model, per block, this emits:

  artifacts/<model>/block_NN.hlo.txt    HLO *text* for fn(activation, *params)
  artifacts/<model>/block_NN.params.bin concatenated f32 LE parameters
  artifacts/<model>/golden_block_NN.bin expected activation after this block
  artifacts/<model>/golden_input.bin    the deterministic test frame

plus a single artifacts/manifest.json carrying every shape, the spatial
resolution trajectory, the full-scale analytical profile (FLOPs, parameter
bytes, boundary tensor bytes, op counts — the inputs to the Rust placement
algorithm), and the Pallas kernel structure metrics (VMEM footprint, MXU
utilization estimate) for the dominant matmul of each block.

Interchange is HLO **text**, not serialized protos: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` crate binds) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.

Python runs ONCE at build time (`make artifacts`); the Rust binary never
imports it.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import matmul as kmm


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, data: bytes) -> str:
    with open(path, "wb") as f:
        f.write(data)
    return hashlib.sha256(data).hexdigest()


def _f32_bytes(arrs) -> bytes:
    return b"".join(np.asarray(a, dtype="<f4").tobytes() for a in arrs)


def _dominant_matmul(arch: M.Arch, metas_tiny, bidx: int):
    """Kernel-structure metrics for the block's largest matmul.

    The conv with the most FLOPs dominates; reconstruct its (M, K, N) from
    the tiny-scale shapes to report VMEM footprint + MXU utilization of the
    Pallas tiling (DESIGN.md §6/§8).
    """
    best = None
    shape = metas_tiny[bidx]["in_shape"]

    def visit(layers, shape):
        nonlocal best
        for ly in layers:
            if isinstance(ly, M.Conv):
                h, w, c = shape
                oh, ow = M._conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
                oc = (
                    arch.tiny_classes
                    if ly.out_ch == M.NUM_CLASSES_FULL
                    else M.scale_ch(ly.out_ch, arch.tiny_width)
                )
                prob = (oh * ow, ly.kernel * ly.kernel * c, oc)
                fl = 2 * prob[0] * prob[1] * prob[2]
                if best is None or fl > best[0]:
                    best = (fl, prob)
                shape = (oh, ow, oc)
            elif isinstance(ly, M.DWConv):
                h, w, c = shape
                oh, ow = M._conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
                shape = (oh, ow, c)
            elif isinstance(ly, M.Pool):
                h, w, c = shape
                oh, ow = M._conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
                shape = (oh, ow, c)
            elif isinstance(ly, M.GAP):
                shape = ("flat", shape[2])
            elif isinstance(ly, M.Dense):
                fin = shape[1] if shape[0] == "flat" else shape[0] * shape[1] * shape[2]
                fout = (
                    arch.tiny_classes
                    if ly.out == M.NUM_CLASSES_FULL
                    else M._r8(ly.out * arch.tiny_width * 0.5)
                )
                prob = (1, fin, fout)
                fl = 2 * fin * fout
                if best is None or fl > best[0]:
                    best = (fl, prob)
                shape = ("flat", fout)
            elif isinstance(ly, M.Parallel):
                outs = []
                for p in ly.paths:
                    outs.append(visit(p, shape))
                if ly.combine == "concat":
                    shape = (outs[0][0], outs[0][1], sum(o[2] for o in outs))
                else:
                    shape = outs[0]
            elif isinstance(ly, M.Identity):
                pass
        return shape

    visit(arch.blocks[bidx].layers, shape)
    if best is None:
        return None
    m, k, n = best[1]
    return dict(
        m=m,
        k=k,
        n=n,
        vmem_bytes=kmm.vmem_footprint_bytes(m, k, n),
        mxu_utilization=round(kmm.mxu_utilization_estimate(m, k, n), 4),
    )


def lower_model(arch: M.Arch, out_dir: str, seed: int) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = M.init_block_params(arch, arch.tiny_width, arch.tiny_classes, seed)
    metas_full = M.block_meta(arch, 1.0, M.NUM_CLASSES_FULL)
    metas_tiny = M.block_meta(arch, arch.tiny_width, arch.tiny_classes)

    x = M.test_frame()
    _write(os.path.join(out_dir, "golden_input.bin"), _f32_bytes([x]))

    blocks = []
    act = x
    for b in range(len(arch.blocks)):
        t0 = time.time()
        ps = params[b]

        def block_fn(a, *flat_params):
            return (M.block_forward(arch, b, a, list(flat_params), interpret=True),)

        arg_specs = [jax.ShapeDtypeStruct(act.shape, jnp.float32)] + [
            jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in ps
        ]
        lowered = jax.jit(block_fn).lower(*arg_specs)
        hlo = to_hlo_text(lowered)
        hlo_rel = f"{arch.name}/block_{b:02d}.hlo.txt"
        with open(os.path.join(out_dir, f"block_{b:02d}.hlo.txt"), "w") as f:
            f.write(hlo)

        params_rel = f"{arch.name}/block_{b:02d}.params.bin"
        pdigest = _write(os.path.join(out_dir, f"block_{b:02d}.params.bin"),
                         _f32_bytes(ps))

        # golden via the pure-jnp oracle (independent of the pallas path)
        act = M.block_forward_ref(arch, b, act, ps)
        gdigest = _write(
            os.path.join(out_dir, f"golden_block_{b:02d}.bin"), _f32_bytes([act])
        )

        mt, mf = metas_tiny[b], metas_full[b]
        out_shape_t = (
            [1, mt["out_shape"][1]]
            if mt["out_shape"][0] == "flat"
            else [1, mt["out_shape"][0], mt["out_shape"][1], mt["out_shape"][2]]
        )
        in_shape_t = (
            [1, mt["in_shape"][1]]
            if mt["in_shape"][0] == "flat"
            else [1, mt["in_shape"][0], mt["in_shape"][1], mt["in_shape"][2]]
        )
        blocks.append(
            dict(
                idx=b,
                name=arch.blocks[b].name,
                hlo=hlo_rel,
                params=params_rel,
                params_sha256=pdigest,
                param_shapes=[list(p.shape) for p in ps],
                param_floats=int(sum(int(np.prod(p.shape)) for p in ps)),
                in_shape=in_shape_t,
                out_shape=out_shape_t,
                in_res=int(mt["in_res"]),
                out_res=int(mt["out_res"]),
                flops_full=int(mf["flops"]),
                param_bytes_full=int(mf["param_floats"] * 4),
                out_bytes_full=int(mf["out_elems"] * 4),
                act_bytes_full=int(mf["act_elems"] * 4),
                peak_act_bytes_full=int(mf["peak_act_elems"] * 4),
                n_ops=int(mf["n_ops"]),
                golden=f"{arch.name}/golden_block_{b:02d}.bin",
                golden_sha256=gdigest,
                kernel=_dominant_matmul(arch, metas_tiny, b),
            )
        )
        print(
            f"  [{arch.name}] block {b:02d} {arch.blocks[b].name:14s} "
            f"hlo={len(hlo)//1024:4d}KiB  t={time.time()-t0:5.1f}s"
        )

    return dict(
        name=arch.name,
        tiny_width=arch.tiny_width,
        tiny_classes=arch.tiny_classes,
        blocks=blocks,
        golden_input=f"{arch.name}/golden_input.bin",
        total_flops_full=int(sum(b["flops_full"] for b in blocks)),
        model_bytes_full=int(sum(b["param_bytes_full"] for b in blocks)),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--models", default=",".join(M.MODEL_NAMES))
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    manifest = dict(
        version=1,
        input_shape=list(M.INPUT_SHAPE),
        seed=args.seed,
        models={},
    )
    for name in args.models.split(","):
        arch = M.ZOO[name]
        print(f"== lowering {name} ({len(arch.blocks)} blocks)")
        manifest["models"][name] = lower_model(
            arch, os.path.join(args.out, name), args.seed
        )
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    n_blocks = sum(len(m["blocks"]) for m in manifest["models"].values())
    print(f"wrote manifest with {len(manifest['models'])} models / {n_blocks} blocks")


if __name__ == "__main__":
    main()
