# Build-time-only package: JAX/Pallas model authoring + AOT lowering.
# Nothing in here is imported at runtime; the Rust binary consumes only
# the artifacts/ directory this package emits.
