# Layer-1 Pallas kernels for the Serdab compute hot-spots, plus the
# pure-jnp oracle (ref.py) they are verified against.
from . import conv2d, matmul, pool, ref  # noqa: F401
