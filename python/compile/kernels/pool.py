"""Layer-1 Pallas kernels: max / average pooling and global average pool.

Pooling is VPU (vector unit) work, not MXU work: the kernel materializes
the KxK strided window views and reduces them elementwise. The whole
feature map for the Serdab models fits comfortably in VMEM (<= 112*112*64
floats ~ 3.2 MB at the tiny calibration widths), so the grid is 1 and the
BlockSpec keeps the full array resident; for full-width models a row-tiled
grid would be used instead (same kernel body).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, kh, kw, stride, oh, ow, mode):
    x = x_ref[...]  # (1, HP, WP, C)
    c = x.shape[3]
    acc = None
    for di in range(kh):
        for dj in range(kw):
            sl = jax.lax.slice(
                x,
                (0, di, dj, 0),
                (1, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            if acc is None:
                acc = sl
            elif mode == "max":
                acc = jnp.maximum(acc, sl)
            else:
                acc = acc + sl
    if mode == "avg":
        acc = acc / float(kh * kw)
    o_ref[...] = acc


def pool2d(
    x: jax.Array,
    *,
    kernel: int,
    stride: int,
    mode: str = "max",
    padding: str = "VALID",
    interpret: bool = True,
) -> jax.Array:
    """Max/avg pool, NHWC, N == 1. VALID or SAME padding.

    Max pool pads with -inf, avg pool with 0 (and divides by the full
    window, matching the TFLite semantics the paper's stack uses).
    """
    _, h, w, c = x.shape
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
        ph = max(0, (oh - 1) * stride + kernel - h)
        pw = max(0, (ow - 1) * stride + kernel - w)
        pv = -jnp.inf if mode == "max" else 0.0
        x = jnp.pad(
            x,
            ((0, 0), (ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2), (0, 0)),
            constant_values=pv,
        )
    else:
        oh, ow = (h - kernel) // stride + 1, (w - kernel) // stride + 1
    hp, wp = x.shape[1], x.shape[2]
    return pl.pallas_call(
        functools.partial(
            _pool_kernel, kh=kernel, kw=kernel, stride=stride, oh=oh, ow=ow, mode=mode
        ),
        grid=(1,),
        in_specs=[pl.BlockSpec((1, hp, wp, c), lambda i: (0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow, c), lambda i: (0, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, oh, ow, c), jnp.float32),
        interpret=interpret,
    )(x)


def _gap_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.mean(x, axis=(1, 2))


def global_avg_pool(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """(1, H, W, C) -> (1, C) global average pool."""
    _, h, w, c = x.shape
    return pl.pallas_call(
        _gap_kernel,
        grid=(1,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda i: (0, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, c), jnp.float32),
        interpret=interpret,
    )(x)
