"""Layer-1 Pallas kernels: conv2d (im2col x MXU matmul), bias+ReLU epilogue.

The paper's compute hot-spot is CNN inference inside an SGX enclave; on the
TPU-shaped stack the same hot-spot is expressed as an im2col patch
extraction feeding the tiled Pallas matmul (matmul.py). This is the
hardware adaptation called out in DESIGN.md §6: instead of porting the
paper's TFLite CPU loops, we tile the (H*W, KH*KW*Cin) x (KH*KW*Cin, Cout)
product for VMEM residency and MXU shape.

Layout: NHWC with N == 1 throughout (the Serdab data path is a stream of
single frames; batching across frames happens at the pipeline level, not
inside a kernel — that is the paper's pipeline-parallelism insight).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm


def _im2col(x: jax.Array, kh: int, kw: int, stride: int, pad: tuple) -> jax.Array:
    """(1, H, W, C) -> (OH*OW, KH*KW*C) patch matrix.

    Uses static strided slices only (TPU-friendly; no gather). ``pad`` is
    ((top, bottom), (left, right)).
    """
    _, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), pad[0], pad[1], (0, 0)))
    hp = h + pad[0][0] + pad[0][1]
    wp = w + pad[1][0] + pad[1][1]
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = jax.lax.slice(
                xp,
                (0, di, dj, 0),
                (1, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(sl.reshape(oh * ow, c))
    # (OH*OW, KH*KW, C) -> (OH*OW, KH*KW*C); ordering matches ref.py and the
    # weight reshape in ``conv2d`` below.
    return jnp.stack(cols, axis=1).reshape(oh * ow, kh * kw * c), oh, ow


def _bias_act_kernel(x_ref, b_ref, o_ref, *, relu: bool):
    v = x_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(v, 0.0) if relu else v


def _bias_act(y: jax.Array, b: jax.Array, relu: bool, interpret: bool) -> jax.Array:
    """Fused bias + activation epilogue as a row-tiled Pallas kernel.

    Whole-array when it fits VMEM (elementwise VPU work is bandwidth-bound;
    one grid step minimizes invocation overhead — §Perf iteration 2),
    row-tiled otherwise.
    """
    m, n = y.shape
    bm = m if (m * n * 8) <= 8 * 1024 * 1024 else (256 if m % 256 == 0 else m)
    return pl.pallas_call(
        functools.partial(_bias_act_kernel, relu=relu),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(y, b.reshape(1, n))


def conv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: str | tuple = "SAME",
    relu: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """2-D convolution via im2col + the tiled Pallas matmul.

    x: (1, H, W, Cin); w: (KH, KW, Cin, Cout); b: (Cout,).
    padding: "SAME", "VALID", or explicit ((t, b), (l, r)).
    """
    _, h, wd, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, f"channel mismatch {cin} vs {cin2}"
    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-wd // stride)
        ph = max(0, (oh - 1) * stride + kh - h)
        pw = max(0, (ow - 1) * stride + kw - wd)
        pad = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    elif padding == "VALID":
        pad = ((0, 0), (0, 0))
    else:
        pad = padding
    patches, oh, ow = _im2col(x, kh, kw, stride, pad)
    wmat = w.reshape(kh * kw * cin, cout)
    y = mm.matmul(patches, wmat, interpret=interpret)
    y = _bias_act(y, b, relu, interpret)
    return y.reshape(1, oh, ow, cout)


def _dwconv_kernel(p_ref, w_ref, b_ref, o_ref, *, relu: bool):
    # p: (BM, KH*KW, C) patch rows; w: (KH*KW, C); reduce the window axis on
    # the VPU (depthwise conv has no MXU contraction — it is elementwise
    # multiply + window reduction per channel).
    v = jnp.sum(p_ref[...] * w_ref[...][None, :, :], axis=1) + b_ref[...]
    o_ref[...] = jnp.maximum(v, 0.0) if relu else v


def dwconv2d(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    stride: int = 1,
    padding: str | tuple = "SAME",
    relu: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Depthwise 2-D convolution (MobileNet), NHWC, N == 1.

    x: (1, H, W, C); w: (KH, KW, C); b: (C,). Each channel is convolved with
    its own KHxKW filter — expressed as patch extraction + a row-tiled VPU
    reduction kernel.
    """
    _, h, wd, c = x.shape
    kh, kw, c2 = w.shape
    assert c == c2
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-wd // stride)
        ph = max(0, (oh - 1) * stride + kh - h)
        pw = max(0, (ow - 1) * stride + kw - wd)
        pad = ((ph // 2, ph - ph // 2), (pw // 2, pw - pw // 2))
    elif padding == "VALID":
        pad = ((0, 0), (0, 0))
    else:
        pad = padding
    xp = jnp.pad(x, ((0, 0), pad[0], pad[1], (0, 0)))
    hp, wp = xp.shape[1], xp.shape[2]
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1
    cols = []
    for di in range(kh):
        for dj in range(kw):
            sl = jax.lax.slice(
                xp,
                (0, di, dj, 0),
                (1, di + (oh - 1) * stride + 1, dj + (ow - 1) * stride + 1, c),
                (1, stride, stride, 1),
            )
            cols.append(sl.reshape(oh * ow, c))
    patches = jnp.stack(cols, axis=1)  # (OH*OW, KH*KW, C)
    m = oh * ow
    # whole-array when the patch tensor fits VMEM (one grid step), else rows
    bm = m if (m * kh * kw * c * 8) <= 8 * 1024 * 1024 else (256 if m % 256 == 0 else m)
    y = pl.pallas_call(
        functools.partial(_dwconv_kernel, relu=relu),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, kh * kw, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((kh * kw, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), jnp.float32),
        interpret=interpret,
    )(patches, w.reshape(kh * kw, c), b.reshape(1, c))
    return y.reshape(1, oh, ow, c)


def conv_flops(h: int, w: int, cin: int, cout: int, kh: int, kw: int, stride: int,
               padding: str = "SAME") -> int:
    """Multiply-accumulate count (x2 for FLOPs) of one conv layer."""
    if padding == "SAME":
        oh, ow = -(-h // stride), -(-w // stride)
    else:
        oh, ow = (h - kh) // stride + 1, (w - kw) // stride + 1
    return 2 * oh * ow * kh * kw * cin * cout
