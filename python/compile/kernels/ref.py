"""Pure-jnp oracle for every Layer-1 Pallas kernel.

These are the ground-truth semantics: python/tests/test_kernels.py sweeps
shapes (hypothesis) and asserts the Pallas kernels match to float32
tolerance, and aot.py uses this module to emit golden activations that the
Rust runtime re-verifies after loading the HLO artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def conv2d(x, w, b, *, stride=1, padding="SAME", relu=True):
    """NHWC conv, w: (KH, KW, Cin, Cout) — jax.lax.conv_general_dilated."""
    if padding in ("SAME", "VALID"):
        pad = padding
    else:
        pad = list(padding)
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    y = y + b.reshape(1, 1, 1, -1)
    return jnp.maximum(y, 0.0) if relu else y


def pool2d(x, *, kernel, stride, mode="max", padding="VALID"):
    if mode == "max":
        init, op = -jnp.inf, jax.lax.max
    else:
        init, op = 0.0, jax.lax.add
    y = jax.lax.reduce_window(
        x,
        init,
        op,
        window_dimensions=(1, kernel, kernel, 1),
        window_strides=(1, stride, stride, 1),
        padding=padding,
    )
    if mode == "avg":
        y = y / float(kernel * kernel)
    return y


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def dense(x, w, b, *, relu=True):
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    return jnp.maximum(y, 0.0) if relu else y
