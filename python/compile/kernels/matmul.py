"""Layer-1 Pallas kernel: tiled matmul — the MXU hot-spot of every Serdab block.

All convolutions and dense layers in the Serdab model zoo reduce to this
kernel (conv via im2col, see conv2d.py). The tiling discipline targets the
TPU memory hierarchy:

  * grid = (M/BM, N/BN): each grid step owns one (BM, BN) output tile.
  * per-step working set = BM*K + K*BN + BM*BN floats, kept under the
    VMEM budget (see ``vmem_footprint_bytes``) — this is the TPU analogue
    of the paper's 128 MB SGX EPC ceiling: compute must be scheduled in
    resident tiles.
  * the inner ``jnp.dot`` maps onto the MXU systolic array; tiles are kept
    MXU-shaped (multiples of 8x128 where the model widths allow; the tiny
    calibration models use smaller tiles, and ``mxu_utilization_estimate``
    reports the resulting padding waste).

On this image Pallas runs interpret=True (CPU PJRT cannot execute Mosaic
custom-calls), so what we optimize/verify is kernel *structure* (footprint,
tile shapes, numerics vs ref.py), not CPU wall-clock.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 is the MXU lane width; 8 the sublane. The tiny
# models override BM/BN downwards when a dimension is smaller than a tile.
DEF_BM = 128
DEF_BN = 128

# VMEM budget per grid step (bytes). Real TPUv4 VMEM is ~16 MiB/core; we
# keep each step's working set well under 1/4 of it so double-buffering
# (next tile prefetch while current computes) fits.
VMEM_BUDGET = 4 * 1024 * 1024


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def pick_tiles(m: int, k: int, n: int) -> tuple[int, int]:
    """Choose (BM, BN) that divide the padded problem and respect VMEM.

    Policy (§Perf iteration 2): take the *largest* BM that keeps the
    working set (BM*K + K*BN + BM*BN) * 4 under the VMEM budget, starting
    from the whole-M extent rounded to the 8-row sublane. Fewer grid steps
    means fewer kernel invocations (and on real TPU, better MXU occupancy
    per step while 2x the budget still leaves room for double-buffering).
    K is never tiled: every matmul in the zoo has K = kh*kw*cin small
    enough to keep resident, which avoids an accumulation loop and the
    associated revolving-buffer hazard.
    """
    bn = min(DEF_BN, max(8, -(-n // 8) * 8))

    def fits(bm_, bn_):
        return (bm_ * k + k * bn_ + bm_ * bn_) * 4 <= VMEM_BUDGET

    # largest power-of-two-ish BM (multiple of 8) that fits
    bm = max(8, -(-m // 8) * 8)
    while not fits(bm, bn) and bm > 8:
        bm = max(8, (bm // 2 + 7) // 8 * 8)
    while not fits(bm, bn) and bn > 8:
        bn //= 2
    return bm, bn


def vmem_footprint_bytes(m: int, k: int, n: int) -> int:
    """Per-grid-step VMEM working set of ``matmul`` for this problem."""
    bm, bn = pick_tiles(m, k, n)
    return (bm * k + k * bn + bm * bn) * 4


def mxu_utilization_estimate(m: int, k: int, n: int) -> float:
    """Fraction of MXU issue slots doing useful work (padding waste only).

    The MXU consumes 128x128 operand tiles; dimensions that are not
    multiples of (8, 128) are padded by the hardware. This is the
    structural estimate recorded in the manifest for DESIGN.md's
    roofline discussion.
    """
    bm, bn = pick_tiles(m, k, n)
    pm = _ceil_div(m, bm) * bm
    pn = _ceil_div(n, bn) * bn
    pk = _ceil_div(k, 128) * 128
    useful = m * k * n
    issued = pm * pk * pn
    return useful / issued if issued else 0.0


def _matmul_kernel(x_ref, w_ref, o_ref):
    # One (BM, BN) output tile per grid step; K resident. jnp.dot lowers to
    # the MXU on real hardware; preferred_element_type pins f32 accumulation.
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x: jax.Array, w: jax.Array, *, interpret: bool = True) -> jax.Array:
    """``x @ w`` via the tiled Pallas kernel.

    x: (M, K) f32, w: (K, N) f32 -> (M, N) f32.
    Pads M and N up to tile multiples, never K (kept resident).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn = pick_tiles(m, k, n)
    pm, pn = _ceil_div(m, bm) * bm, _ceil_div(n, bn) * bn
    xp = jnp.pad(x, ((0, pm - m), (0, 0))) if pm != m else x
    wp = jnp.pad(w, ((0, 0), (0, pn - n))) if pn != n else w

    out = pl.pallas_call(
        _matmul_kernel,
        grid=(pm // bm, pn // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pm, pn), jnp.float32),
        interpret=interpret,
    )(xp, wp)
    return out[:m, :n]
