"""Layer-2 JAX model zoo: the five CNNs Serdab evaluates, as *block* chains.

Each architecture (GoogLeNet, AlexNet, ResNet, MobileNet, SqueezeNet) is
described once at **full channel scale** — that description is the source of
the analytical profile (FLOPs, parameter bytes, boundary-tensor bytes, spatial
resolution) the Rust placement algorithm uses for the paper-scale experiments
— and is **instantiated at a tiny width multiplier** for the executable
artifacts, preserving the layer structure and, crucially, the spatial
*resolution trajectory* (stride/pool schedule), which is what the paper's
privacy metric (resolution <= delta = 20x20) depends on.

A *block* is the unit of partitioning: the paper partitions at layer
granularity; our blocks correspond to the paper's "layers" L_x (it treats an
inception module as one partitionable unit). Every block is lowered to its own
HLO module by aot.py, so the Rust coordinator can execute any contiguous block
range on any device — that is what makes arbitrary placement paths runnable.

All forward math routes through the Layer-1 Pallas kernels (kernels/), with a
pure-jnp mirror (forward_ref) against kernels/ref.py used for goldens and
pytest equivalence.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d as kconv
from .kernels import pool as kpool
from .kernels import matmul as kmm
from .kernels import ref as kref

# ---------------------------------------------------------------------------
# Layer description types (full-scale channel counts; width_mult applied at
# instantiation time).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Conv:
    kernel: int
    stride: int
    out_ch: int
    padding: object = "SAME"  # "SAME" | "VALID" | ((t,b),(l,r))
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class DWConv:
    """Depthwise conv (MobileNet); out channels == in channels."""

    kernel: int
    stride: int
    padding: object = "SAME"
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class Pool:
    kernel: int
    stride: int
    mode: str = "max"  # "max" | "avg"
    padding: str = "VALID"


@dataclasses.dataclass(frozen=True)
class GAP:
    pass


@dataclasses.dataclass(frozen=True)
class Dense:
    out: int
    relu: bool = True


@dataclasses.dataclass(frozen=True)
class Parallel:
    """Multi-path module: inception (concat), fire expand (concat),
    residual (add). Each path is a sequence of layers applied to the same
    input; ``combine`` merges path outputs; ``post_relu`` applies a ReLU to
    the merged result (ResNet)."""

    paths: Tuple[Tuple[object, ...], ...]
    combine: str = "concat"  # "concat" | "add"
    post_relu: bool = False


@dataclasses.dataclass(frozen=True)
class Identity:
    pass


@dataclasses.dataclass(frozen=True)
class Block:
    name: str
    layers: Tuple[object, ...]


@dataclasses.dataclass(frozen=True)
class Arch:
    name: str
    blocks: Tuple[Block, ...]
    # width multiplier used for the executable (tiny) instantiation
    tiny_width: float = 0.125
    tiny_classes: int = 10


INPUT_SHAPE = (1, 224, 224, 3)
NUM_CLASSES_FULL = 1000


def _r8(c: float) -> int:
    """Round a scaled channel count to a multiple of 8, min 8 (VPU lanes)."""
    return max(8, int(math.ceil(c / 8.0)) * 8)


def scale_ch(c: int, width: float) -> int:
    return _r8(c * width)


# ---------------------------------------------------------------------------
# Architecture zoo (full-scale descriptions)
# ---------------------------------------------------------------------------


def _inception(n, c1, c3r, c3, c5r, c5, cp) -> Block:
    return Block(
        n,
        (
            Parallel(
                paths=(
                    (Conv(1, 1, c1),),
                    (Conv(1, 1, c3r), Conv(3, 1, c3)),
                    (Conv(1, 1, c5r), Conv(5, 1, c5)),
                    (Pool(3, 1, "max", "SAME"), Conv(1, 1, cp)),
                ),
            ),
        ),
    )


def _fire(n, s, e) -> Block:
    return Block(
        n,
        (
            Conv(1, 1, s),
            Parallel(paths=((Conv(1, 1, e),), (Conv(3, 1, e),))),
        ),
    )


def _res_block(n, c, stride, project) -> Block:
    """Bottleneck residual block (ResNet-50 style): 1x1 c → 3x3 c → 1x1 4c."""
    main = (
        Conv(1, stride, c),
        Conv(3, 1, c),
        Conv(1, 1, 4 * c, relu=False),
    )
    shortcut = (Conv(1, stride, 4 * c, relu=False),) if project else (Identity(),)
    return Block(n, (Parallel(paths=(main, shortcut), combine="add", post_relu=True),))


def _dsw(n, cout, stride) -> Block:
    return Block(n, (DWConv(3, stride), Conv(1, 1, cout)))


ALEXNET = Arch(
    "alexnet",
    (
        Block("conv1", (Conv(11, 4, 96, ((2, 2), (2, 2))),)),
        Block("pool1_conv2", (Pool(3, 2), Conv(5, 1, 256))),
        Block("pool2_conv3", (Pool(3, 2), Conv(3, 1, 384))),
        Block("conv4", (Conv(3, 1, 384),)),
        Block("conv5_pool5", (Conv(3, 1, 256), Pool(3, 2))),
        Block("fc6", (Dense(4096),)),
        Block("fc7", (Dense(4096),)),
        Block("fc8", (Dense(NUM_CLASSES_FULL, relu=False),)),
    ),
)

GOOGLENET = Arch(
    "googlenet",
    (
        Block("conv1_pool1", (Conv(7, 2, 64), Pool(3, 2, "max", "SAME"))),
        Block(
            "conv2_pool2",
            (Conv(1, 1, 64), Conv(3, 1, 192), Pool(3, 2, "max", "SAME")),
        ),
        _inception("inc3a", 64, 96, 128, 16, 32, 32),
        Block(
            "inc3b_pool3",
            _inception("x", 128, 128, 192, 32, 96, 64).layers
            + (Pool(3, 2, "max", "SAME"),),
        ),
        _inception("inc4a", 192, 96, 208, 16, 48, 64),
        _inception("inc4b", 160, 112, 224, 24, 64, 64),
        _inception("inc4c", 128, 128, 256, 24, 64, 64),
        _inception("inc4d", 112, 144, 288, 32, 64, 64),
        Block(
            "inc4e_pool4",
            _inception("x", 256, 160, 320, 32, 128, 128).layers
            + (Pool(3, 2, "max", "SAME"),),
        ),
        _inception("inc5a", 256, 160, 320, 32, 128, 128),
        _inception("inc5b", 384, 192, 384, 48, 128, 128),
        Block("head", (GAP(), Dense(NUM_CLASSES_FULL, relu=False))),
    ),
)

# ResNet-50-like: bottleneck stages [3, 4, 6, 3]. Consecutive identity
# blocks within a stage are grouped pairwise to keep the partition-unit
# count near the paper's layer granularity (16 residual units -> 11 blocks).
RESNET = Arch(
    "resnet",
    (
        Block("conv1_pool1", (Conv(7, 2, 64), Pool(3, 2, "max", "SAME"))),
        _res_block("res2a", 64, 1, True),
        Block("res2bc", _res_block("x", 64, 1, False).layers * 2),
        _res_block("res3a", 128, 2, True),
        Block("res3bc", _res_block("x", 128, 1, False).layers * 2),
        _res_block("res3d", 128, 1, False),
        _res_block("res4a", 256, 2, True),
        Block("res4bc", _res_block("x", 256, 1, False).layers * 2),
        Block("res4de", _res_block("x", 256, 1, False).layers * 2),
        _res_block("res4f", 256, 1, False),
        _res_block("res5a", 512, 2, True),
        Block("res5bc", _res_block("x", 512, 1, False).layers * 2),
        Block("head", (GAP(), Dense(NUM_CLASSES_FULL, relu=False))),
    ),
)

MOBILENET = Arch(
    "mobilenet",
    (
        Block("conv1", (Conv(3, 2, 32),)),
        _dsw("dsw1", 64, 1),
        _dsw("dsw2", 128, 2),
        _dsw("dsw3", 128, 1),
        _dsw("dsw4", 256, 2),
        _dsw("dsw5", 256, 1),
        _dsw("dsw6", 512, 2),
        _dsw("dsw7", 512, 1),
        _dsw("dsw8", 512, 1),
        _dsw("dsw9", 512, 1),
        _dsw("dsw10", 512, 1),
        _dsw("dsw11", 512, 1),
        _dsw("dsw12", 1024, 2),
        _dsw("dsw13", 1024, 1),
        Block("head", (GAP(), Dense(NUM_CLASSES_FULL, relu=False))),
    ),
)

SQUEEZENET = Arch(
    "squeezenet",
    (
        Block("conv1_pool1", (Conv(7, 2, 96), Pool(3, 2))),
        _fire("fire2", 16, 64),
        _fire("fire3", 16, 64),
        Block("fire4_pool4", _fire("x", 32, 128).layers + (Pool(3, 2),)),
        _fire("fire5", 32, 128),
        _fire("fire6", 48, 192),
        _fire("fire7", 48, 192),
        Block("fire8_pool8", _fire("x", 64, 256).layers + (Pool(3, 2),)),
        _fire("fire9", 64, 256),
        Block("head", (Conv(1, 1, NUM_CLASSES_FULL, relu=True), GAP())),
    ),
)

ZOO = {a.name: a for a in (GOOGLENET, ALEXNET, RESNET, MOBILENET, SQUEEZENET)}
MODEL_NAMES = ("googlenet", "alexnet", "resnet", "mobilenet", "squeezenet")


# ---------------------------------------------------------------------------
# Shape / cost inference (pure python; drives both instantiation and the
# analytical profile the manifest carries to Rust).
# ---------------------------------------------------------------------------


def _conv_out_hw(h: int, w: int, k: int, s: int, padding) -> Tuple[int, int]:
    if padding == "SAME":
        return -(-h // s), -(-w // s)
    if padding == "VALID":
        return (h - k) // s + 1, (w - k) // s + 1
    (pt, pb), (pl_, pr) = padding
    return (h + pt + pb - k) // s + 1, (w + pl_ + pr - k) // s + 1


@dataclasses.dataclass
class LayerCost:
    name: str
    flops: int
    param_floats: int
    out_elems: int
    n_ops: int


def _walk_layers(
    layers: Sequence[object], shape, width: float, classes: int, costs: Optional[list]
):
    """Propagate (h, w, c) or ('flat', f) through a layer sequence at the
    given width multiplier, appending per-primitive costs."""

    def ch(c):
        return scale_ch(c, width) if width != 1.0 else c

    for ly in layers:
        if isinstance(ly, Conv):
            h, w, c = shape
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            oc = ch(ly.out_ch)
            if costs is not None:
                costs.append(
                    LayerCost(
                        "conv",
                        2 * oh * ow * ly.kernel * ly.kernel * c * oc,
                        ly.kernel * ly.kernel * c * oc + oc,
                        oh * ow * oc,
                        1,
                    )
                )
            shape = (oh, ow, oc)
        elif isinstance(ly, DWConv):
            h, w, c = shape
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            if costs is not None:
                costs.append(
                    LayerCost(
                        "dwconv",
                        2 * oh * ow * ly.kernel * ly.kernel * c,
                        ly.kernel * ly.kernel * c + c,
                        oh * ow * c,
                        1,
                    )
                )
            shape = (oh, ow, c)
        elif isinstance(ly, Pool):
            h, w, c = shape
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            if costs is not None:
                costs.append(
                    LayerCost("pool", oh * ow * ly.kernel * ly.kernel * c, 0, oh * ow * c, 1)
                )
            shape = (oh, ow, c)
        elif isinstance(ly, GAP):
            h, w, c = shape
            if costs is not None:
                costs.append(LayerCost("gap", h * w * c, 0, c, 1))
            shape = ("flat", c)
        elif isinstance(ly, Dense):
            if shape[0] == "flat":
                fin = shape[1]
            else:
                h, w, c = shape
                fin = h * w * c
            fout = classes if ly.out == NUM_CLASSES_FULL else ch(ly.out)
            if width == 1.0:
                fout = ly.out
            elif ly.out != NUM_CLASSES_FULL:
                fout = _r8(ly.out * width * 0.5)  # FCs shrink harder (memory)
            if costs is not None:
                costs.append(LayerCost("dense", 2 * fin * fout, fin * fout + fout, fout, 1))
            shape = ("flat", fout)
        elif isinstance(ly, Identity):
            pass
        elif isinstance(ly, Parallel):
            h, w, c = shape
            outs = []
            for path in ly.paths:
                s2 = shape
                s2 = _walk_layers(path, s2, width, classes, costs)
                outs.append(s2)
            if ly.combine == "concat":
                oh, ow = outs[0][0], outs[0][1]
                shape = (oh, ow, sum(o[2] for o in outs))
            else:  # add
                shape = outs[0]
                if costs is not None:
                    costs.append(
                        LayerCost("add", outs[0][0] * outs[0][1] * outs[0][2], 0,
                                  outs[0][0] * outs[0][1] * outs[0][2], 0)
                    )
        else:
            raise TypeError(f"unknown layer {ly!r}")
    return shape


def block_meta(arch: Arch, width: float, classes: int):
    """Per-block metadata at a given width: shapes, resolution, costs."""
    shape = (INPUT_SHAPE[1], INPUT_SHAPE[2], INPUT_SHAPE[3])
    metas = []
    for blk in arch.blocks:
        costs: List[LayerCost] = []
        in_shape = shape
        shape = _walk_layers(blk.layers, shape, width, classes, costs)
        metas.append(
            dict(
                name=blk.name,
                in_shape=in_shape,
                out_shape=shape,
                in_res=(in_shape[0] if in_shape[0] != "flat" else 1),
                out_res=(shape[0] if shape[0] != "flat" else 1),
                flops=sum(c.flops for c in costs),
                param_floats=sum(c.param_floats for c in costs),
                out_elems=(
                    shape[1] if shape[0] == "flat" else shape[0] * shape[1] * shape[2]
                ),
                # total activation traffic (sum of every primitive's output)
                # and the largest single intermediate — these drive the
                # enclave working-set / paging model on the Rust side
                act_elems=sum(c.out_elems for c in costs),
                peak_act_elems=max((c.out_elems for c in costs), default=0),
                n_ops=sum(c.n_ops for c in costs),
            )
        )
    return metas


# ---------------------------------------------------------------------------
# Parameter construction + forward execution (tiny scale)
# ---------------------------------------------------------------------------


def _init_params_layers(layers, shape, width, classes, key, out):
    def ch(c):
        return scale_ch(c, width)

    for ly in layers:
        if isinstance(ly, Conv):
            h, w, c = shape
            oc = ch(ly.out_ch)
            key, k1 = jax.random.split(key)
            fan_in = ly.kernel * ly.kernel * c
            wgt = jax.random.normal(k1, (ly.kernel, ly.kernel, c, oc), jnp.float32)
            wgt = wgt * jnp.sqrt(2.0 / fan_in)
            out.append(wgt)
            out.append(jnp.zeros((oc,), jnp.float32))
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            shape = (oh, ow, oc)
        elif isinstance(ly, DWConv):
            h, w, c = shape
            key, k1 = jax.random.split(key)
            wgt = jax.random.normal(k1, (ly.kernel, ly.kernel, c), jnp.float32)
            wgt = wgt * jnp.sqrt(2.0 / (ly.kernel * ly.kernel))
            out.append(wgt)
            out.append(jnp.zeros((c,), jnp.float32))
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            shape = (oh, ow, c)
        elif isinstance(ly, Pool):
            h, w, c = shape
            oh, ow = _conv_out_hw(h, w, ly.kernel, ly.stride, ly.padding)
            shape = (oh, ow, c)
        elif isinstance(ly, GAP):
            shape = ("flat", shape[2])
        elif isinstance(ly, Dense):
            fin = shape[1] if shape[0] == "flat" else shape[0] * shape[1] * shape[2]
            if ly.out == NUM_CLASSES_FULL:
                fout = classes
            else:
                fout = _r8(ly.out * width * 0.5)
            key, k1 = jax.random.split(key)
            wgt = jax.random.normal(k1, (fin, fout), jnp.float32) * jnp.sqrt(2.0 / fin)
            out.append(wgt)
            out.append(jnp.zeros((fout,), jnp.float32))
            shape = ("flat", fout)
        elif isinstance(ly, Identity):
            pass
        elif isinstance(ly, Parallel):
            outs = []
            for path in ly.paths:
                key, k1 = jax.random.split(key)
                s2 = _init_params_layers(path, shape, width, classes, k1, out)
                outs.append(s2)
            if ly.combine == "concat":
                shape = (outs[0][0], outs[0][1], sum(o[2] for o in outs))
            else:
                shape = outs[0]
        else:
            raise TypeError(f"unknown layer {ly!r}")
    return shape


def init_block_params(arch: Arch, width: float, classes: int, seed: int):
    """Returns: list (per block) of flat param lists, deterministic in seed."""
    shape = (INPUT_SHAPE[1], INPUT_SHAPE[2], INPUT_SHAPE[3])
    all_params = []
    key = jax.random.PRNGKey(seed)
    for blk in arch.blocks:
        key, bk = jax.random.split(key)
        ps: List[jax.Array] = []
        shape = _init_params_layers(blk.layers, shape, width, classes, bk, ps)
        all_params.append(ps)
    return all_params


class _ParamCursor:
    def __init__(self, params):
        self.params = list(params)
        self.i = 0

    def take(self, n=2):
        got = self.params[self.i : self.i + n]
        self.i += n
        return got


def _fwd_layers(layers, x, cur, width, classes, *, use_ref: bool, interpret: bool):
    kc = kref if use_ref else None
    for ly in layers:
        if isinstance(ly, Conv):
            w, b = cur.take()
            if x.ndim == 2:
                raise ValueError("conv after flatten")
            if use_ref:
                x = kref.conv2d(x, w, b, stride=ly.stride, padding=ly.padding, relu=ly.relu)
            else:
                x = kconv.conv2d(
                    x, w, b, stride=ly.stride, padding=ly.padding, relu=ly.relu,
                    interpret=interpret,
                )
        elif isinstance(ly, DWConv):
            w, b = cur.take()
            if use_ref:
                # depthwise == grouped conv with feature_group_count=C
                c = x.shape[3]
                wr = w.reshape(ly.kernel, ly.kernel, 1, c)
                y = jax.lax.conv_general_dilated(
                    x, wr, (ly.stride, ly.stride), ly.padding,
                    dimension_numbers=("NHWC", "HWIO", "NHWC"),
                    feature_group_count=c,
                )
                y = y + b.reshape(1, 1, 1, -1)
                x = jnp.maximum(y, 0.0) if ly.relu else y
            else:
                x = kconv.dwconv2d(
                    x, w, b, stride=ly.stride, padding=ly.padding, relu=ly.relu,
                    interpret=interpret,
                )
        elif isinstance(ly, Pool):
            if use_ref:
                x = kref.pool2d(x, kernel=ly.kernel, stride=ly.stride, mode=ly.mode,
                                padding=ly.padding)
            else:
                x = kpool.pool2d(x, kernel=ly.kernel, stride=ly.stride, mode=ly.mode,
                                 padding=ly.padding, interpret=interpret)
        elif isinstance(ly, GAP):
            if use_ref:
                x = kref.global_avg_pool(x)
            else:
                x = kpool.global_avg_pool(x, interpret=interpret)
        elif isinstance(ly, Dense):
            w, b = cur.take()
            if x.ndim == 4:
                x = x.reshape(1, -1)
            if use_ref:
                x = kref.dense(x, w, b, relu=ly.relu)
            else:
                y = kmm.matmul(x, w, interpret=interpret) + b
                x = jnp.maximum(y, 0.0) if ly.relu else y
        elif isinstance(ly, Identity):
            pass
        elif isinstance(ly, Parallel):
            outs = []
            for path in ly.paths:
                outs.append(
                    _fwd_layers(path, x, cur, width, classes, use_ref=use_ref,
                                interpret=interpret)
                )
            if ly.combine == "concat":
                x = jnp.concatenate(outs, axis=3)
            else:
                x = outs[0]
                for o in outs[1:]:
                    x = x + o
            if ly.post_relu:
                x = jnp.maximum(x, 0.0)
        else:
            raise TypeError(f"unknown layer {ly!r}")
    return x


def block_forward(arch: Arch, bidx: int, x, params, *, interpret: bool = True):
    """Forward one block through the Pallas kernels."""
    cur = _ParamCursor(params)
    y = _fwd_layers(
        arch.blocks[bidx].layers, x, cur, arch.tiny_width, arch.tiny_classes,
        use_ref=False, interpret=interpret,
    )
    assert cur.i == len(cur.params), f"unused params in {arch.name}[{bidx}]"
    return y


def block_forward_ref(arch: Arch, bidx: int, x, params):
    """Forward one block through the pure-jnp oracle."""
    cur = _ParamCursor(params)
    y = _fwd_layers(
        arch.blocks[bidx].layers, x, cur, arch.tiny_width, arch.tiny_classes,
        use_ref=True, interpret=True,
    )
    assert cur.i == len(cur.params)
    return y


def model_forward_ref(arch: Arch, x, all_params):
    for i in range(len(arch.blocks)):
        x = block_forward_ref(arch, i, x, all_params[i])
    return x


def test_frame(seed: int = 7) -> jax.Array:
    """Deterministic 224x224x3 synthetic frame used for goldens."""
    key = jax.random.PRNGKey(seed)
    base = jax.random.uniform(key, INPUT_SHAPE, jnp.float32)
    # superimpose a deterministic gradient so the frame is not pure noise
    yy = jnp.linspace(0.0, 1.0, INPUT_SHAPE[1]).reshape(1, -1, 1, 1)
    xx = jnp.linspace(0.0, 1.0, INPUT_SHAPE[2]).reshape(1, 1, -1, 1)
    return 0.5 * base + 0.3 * yy + 0.2 * xx
