"""Layer-2 correctness: model zoo structure, shapes, determinism, and
pallas-vs-ref equivalence block by block."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_resolution_trajectory_monotone_nonincreasing(name):
    metas = M.block_meta(M.ZOO[name], 1.0, M.NUM_CLASSES_FULL)
    res = [m["out_res"] for m in metas]
    assert all(a >= b for a, b in zip(res, res[1:])), res
    assert res[-1] == 1  # every model ends in a classifier vector


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_every_model_crosses_privacy_threshold(name):
    # delta = 20x20 (paper §VI-B): every model must eventually produce an
    # intermediate output below it, otherwise no offload is ever legal.
    metas = M.block_meta(M.ZOO[name], 1.0, M.NUM_CLASSES_FULL)
    assert any(m["out_res"] <= 20 for m in metas)


def test_full_scale_profiles_match_published_models():
    # sanity-calibration of the analytical profile against well-known
    # numbers (tolerances are loose; these catch transcription errors).
    gf = {
        n: sum(m["flops"] for m in M.block_meta(M.ZOO[n], 1.0, 1000)) / 1e9
        for n in M.MODEL_NAMES
    }
    pb = {
        n: sum(m["param_floats"] for m in M.block_meta(M.ZOO[n], 1.0, 1000)) * 4 / 1e6
        for n in M.MODEL_NAMES
    }
    assert 2.5 < gf["googlenet"] < 4.0
    assert 1.3 < gf["alexnet"] < 3.0
    assert 0.9 < gf["mobilenet"] < 1.4
    assert 220 < pb["alexnet"] < 260  # AlexNet ~ 240 MB
    assert 20 < pb["googlenet"] < 35
    assert pb["squeezenet"] < 8  # SqueezeNet ~ 5 MB
    assert 12 < pb["mobilenet"] < 20


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_tiny_resolution_trajectory_equals_full(name):
    # the privacy metric depends only on the stride/pool schedule, which the
    # tiny instantiation must preserve exactly
    full = [m["out_res"] for m in M.block_meta(M.ZOO[name], 1.0, 1000)]
    tiny = [
        m["out_res"]
        for m in M.block_meta(M.ZOO[name], M.ZOO[name].tiny_width, M.ZOO[name].tiny_classes)
    ]
    assert full == tiny


def test_init_params_deterministic():
    a = M.init_block_params(M.ZOO["alexnet"], 0.125, 10, 42)
    b = M.init_block_params(M.ZOO["alexnet"], 0.125, 10, 42)
    for pa, pb_ in zip(a, b):
        for x, y in zip(pa, pb_):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_test_frame_deterministic_and_bounded():
    f1, f2 = M.test_frame(), M.test_frame()
    np.testing.assert_array_equal(np.asarray(f1), np.asarray(f2))
    a = np.asarray(f1)
    assert a.shape == M.INPUT_SHAPE and a.min() >= 0.0 and a.max() <= 1.0


@pytest.mark.parametrize("name", ["squeezenet", "resnet"])
def test_block_chain_pallas_matches_ref(name):
    arch = M.ZOO[name]
    ps = M.init_block_params(arch, arch.tiny_width, arch.tiny_classes, 42)
    x = M.test_frame()
    for b in range(len(arch.blocks)):
        yp = M.block_forward(arch, b, x, ps[b])
        yr = M.block_forward_ref(arch, b, x, ps[b])
        np.testing.assert_allclose(
            np.asarray(yp), np.asarray(yr), rtol=2e-4, atol=2e-4
        )
        x = yr


@pytest.mark.parametrize("name", M.MODEL_NAMES)
def test_block_shapes_chain(name):
    # out_shape of block i must equal in_shape of block i+1 (the contract
    # the Rust chain executor relies on)
    arch = M.ZOO[name]
    metas = M.block_meta(arch, arch.tiny_width, arch.tiny_classes)
    for a, b in zip(metas, metas[1:]):
        # flatten boundaries are allowed: conv (h,w,c) -> dense consumes h*w*c
        if a["out_shape"][0] != "flat" and b["in_shape"][0] == "flat":
            h, w, c = a["out_shape"]
            assert h * w * c == b["in_shape"][1]
        else:
            assert a["out_shape"] == b["in_shape"]
