"""Shared pytest config for the compile-layer tests.

Two jobs:
  1. Make ``from compile...`` imports work from any CWD by putting the
     ``python/`` directory on sys.path.
  2. Skip (not fail) tests whose optional dependencies are unavailable —
     CI runs the compile-layer job on machines that may not have a JAX
     wheel (or hypothesis) for their platform. JAX missing skips the
     whole suite; hypothesis missing skips only the kernel sweeps.
"""

import importlib.util
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))


def _missing(*names):
    return [n for n in names if importlib.util.find_spec(n) is None]


collect_ignore_glob = []
_skipped = _missing("jax", "numpy")
if _skipped:
    # Everything in the compile layer needs JAX + numpy.
    collect_ignore_glob = ["test_*.py"]
elif _missing("hypothesis"):
    _skipped = ["hypothesis"]
    collect_ignore_glob = ["test_kernels.py"]


def pytest_report_header(config):
    if _skipped:
        return f"compile-layer: some tests skipped (missing {', '.join(_skipped)})"
    return None
