"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/strides/paddings; assert_allclose at float32
tolerance. This is the core L1 correctness signal: if these pass, the HLO
emitted by aot.py computes ref.py semantics.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as kconv
from compile.kernels import matmul as kmm
from compile.kernels import pool as kpool
from compile.kernels import ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 300),
    k=st.integers(1, 64),
    n=st.integers(1, 200),
)
def test_matmul_matches_ref(m, k, n):
    x, w = _rand(m * 7 + 1, (m, k)), _rand(n * 13 + 2, (k, n))
    got = kmm.matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul(x, w)), rtol=1e-4, atol=1e-4
    )


def test_matmul_tile_padding_exact():
    # non-multiple-of-tile M and N must be sliced back exactly
    x, w = _rand(1, (129, 27)), _rand(2, (27, 130))
    got = kmm.matmul(x, w)
    assert got.shape == (129, 130)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(ref.matmul(x, w)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("m,k,n", [(1, 9216, 256), (3136, 72, 24), (12544, 147, 16)])
def test_matmul_zoo_shapes(m, k, n):
    x, w = _rand(3, (m, k)), _rand(4, (k, n))
    np.testing.assert_allclose(
        np.asarray(kmm.matmul(x, w)),
        np.asarray(ref.matmul(x, w)),
        rtol=2e-4,
        atol=2e-4,
    )


def test_pick_tiles_respects_vmem_budget():
    for m, k, n in [(12544, 147, 64), (3136, 1152, 256), (1, 9216, 512)]:
        assert kmm.vmem_footprint_bytes(m, k, n) <= kmm.VMEM_BUDGET


def test_mxu_utilization_in_unit_interval():
    for m, k, n in [(4, 3, 5), (128, 128, 128), (3136, 27, 16)]:
        u = kmm.mxu_utilization_estimate(m, k, n)
        assert 0.0 < u <= 1.0
    # perfectly tiled problem wastes nothing
    assert kmm.mxu_utilization_estimate(128, 128, 128) == 1.0


# ---------------------------------------------------------------- conv2d


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(6, 40),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([8, 16]),
    k=st.sampled_from([1, 3, 5]),
    s=st.sampled_from([1, 2]),
    pad=st.sampled_from(["SAME", "VALID"]),
    relu=st.booleans(),
)
def test_conv2d_matches_ref(h, cin, cout, k, s, pad, relu):
    if pad == "VALID" and h < k:
        return
    x = _rand(h * 31 + cin, (1, h, h, cin))
    w = _rand(cout * 17 + k, (k, k, cin, cout)) * 0.1
    b = _rand(5, (cout,)) * 0.1
    got = kconv.conv2d(x, w, b, stride=s, padding=pad, relu=relu)
    want = ref.conv2d(x, w, b, stride=s, padding=pad, relu=relu)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_conv2d_explicit_padding_alexnet_conv1():
    x = _rand(1, (1, 224, 224, 3))
    w = _rand(2, (11, 11, 3, 16)) * 0.05
    b = jnp.zeros((16,))
    got = kconv.conv2d(x, w, b, stride=4, padding=((2, 2), (2, 2)))
    want = ref.conv2d(x, w, b, stride=4, padding=((2, 2), (2, 2)))
    assert got.shape == (1, 55, 55, 16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(8, 36),
    c=st.sampled_from([8, 16]),
    s=st.sampled_from([1, 2]),
)
def test_dwconv2d_matches_grouped_conv(h, c, s):
    x = _rand(h, (1, h, h, c))
    w = _rand(c, (3, 3, c)) * 0.2
    b = _rand(9, (c,)) * 0.1
    got = kconv.dwconv2d(x, w, b, stride=s, padding="SAME")
    wr = w.reshape(3, 3, 1, c)
    y = jax.lax.conv_general_dilated(
        x, wr, (s, s), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    ) + b.reshape(1, 1, 1, -1)
    want = jnp.maximum(y, 0.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- pooling


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(6, 40),
    c=st.sampled_from([4, 8]),
    k=st.sampled_from([2, 3]),
    s=st.sampled_from([1, 2]),
    mode=st.sampled_from(["max", "avg"]),
    pad=st.sampled_from(["VALID", "SAME"]),
)
def test_pool2d_matches_ref(h, c, k, s, mode, pad):
    if pad == "VALID" and h < k:
        return
    x = _rand(h * 3 + c, (1, h, h, c))
    got = kpool.pool2d(x, kernel=k, stride=s, mode=mode, padding=pad)
    want = ref.pool2d(x, kernel=k, stride=s, mode=mode, padding=pad)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_global_avg_pool_matches_ref():
    x = _rand(11, (1, 7, 7, 32))
    np.testing.assert_allclose(
        np.asarray(kpool.global_avg_pool(x)),
        np.asarray(ref.global_avg_pool(x)),
        rtol=1e-5,
        atol=1e-6,
    )


def test_maxpool_same_padding_uses_neg_inf():
    # all-negative inputs: SAME zero-padding would corrupt a max pool
    x = -jnp.ones((1, 5, 5, 4), jnp.float32)
    got = kpool.pool2d(x, kernel=3, stride=2, mode="max", padding="SAME")
    assert float(np.max(np.asarray(got))) == -1.0
