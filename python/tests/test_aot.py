"""AOT artifact contract tests: manifest structure, digests, golden shapes.

These run against artifacts/ if present (make artifacts); they are the
python half of the interchange contract the Rust runtime tests re-verify.
"""

import hashlib
import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def _manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_has_all_models():
    m = _manifest()
    assert set(m["models"]) == {
        "googlenet", "alexnet", "resnet", "mobilenet", "squeezenet"
    }


def test_block_files_exist_and_digests_match():
    m = _manifest()
    for model in m["models"].values():
        for blk in model["blocks"]:
            hlo = os.path.join(ART, blk["hlo"])
            assert os.path.exists(hlo), hlo
            with open(hlo) as f:
                head = f.read(64)
            assert "HloModule" in head
            for key, rel in (("params_sha256", "params"), ("golden_sha256", "golden")):
                path = os.path.join(ART, blk[rel])
                with open(path, "rb") as f:
                    data = f.read()
                assert hashlib.sha256(data).hexdigest() == blk[key], path


def test_param_bin_sizes_match_shapes():
    m = _manifest()
    for model in m["models"].values():
        for blk in model["blocks"]:
            n = sum(int(np.prod(s)) for s in blk["param_shapes"])
            assert n == blk["param_floats"]
            size = os.path.getsize(os.path.join(ART, blk["params"]))
            assert size == 4 * n, blk["hlo"]


def test_golden_chain_shapes():
    m = _manifest()
    for model in m["models"].values():
        for blk in model["blocks"]:
            elems = int(np.prod(blk["out_shape"]))
            size = os.path.getsize(os.path.join(ART, blk["golden"]))
            assert size == 4 * elems, blk["golden"]


def test_resolution_trajectory_recorded():
    m = _manifest()
    for model in m["models"].values():
        res = [b["out_res"] for b in model["blocks"]]
        assert all(a >= b for a, b in zip(res, res[1:]))
        assert any(r <= 20 for r in res)  # privacy threshold reachable


def test_kernel_structure_metrics_present():
    m = _manifest()
    for model in m["models"].values():
        # every block with a matmul-shaped op carries VMEM/MXU metrics
        with_kernel = [b for b in model["blocks"] if b["kernel"]]
        assert with_kernel, model["name"]
        for blk in with_kernel:
            assert blk["kernel"]["vmem_bytes"] <= 4 * 1024 * 1024
            assert 0.0 < blk["kernel"]["mxu_utilization"] <= 1.0
