#!/usr/bin/env bash
# Perf-trend gate over BENCH_batching.json (written by
# `cargo bench --bench batching_bench -- --json`).
#
# The gate is deliberately coarse — it fails only on order-of-magnitude
# wrongness, not run-to-run jitter:
#   1. parity must be true: the batched path is worthless the moment it
#      stops being bitwise identical to sequential execution;
#   2. frames/sec at B=8 must be at least MIN_SPEEDUP (default 1.2×) of
#      the batch-1 baseline: if coalescing stops paying for itself the
#      batching machinery has regressed into pure overhead.
#
# Portability rules (so a checkout without a fresh bench run, or a
# laptop-generated artifact checked on CI, never fails spuriously):
#   - a missing artifact WARNS and passes (nothing to gate);
#   - the speedup floor is only enforced when the artifact's "machine"
#     stamp matches this host's class ($(uname -m)-$(nproc)cpu) — perf
#     numbers from different hardware are a trend, not a contract;
#   - parity=false and degenerate rows FAIL regardless of machine:
#     correctness travels with the artifact.
# STRICT=1 restores hard failure for both relaxations (CI perf lane).
#
# Usage: scripts/check_bench.sh [path/to/BENCH_batching.json]
set -euo pipefail

bench="${1:-BENCH_batching.json}"
min_speedup="${MIN_SPEEDUP:-1.2}"
strict="${STRICT:-0}"
host_machine="$(uname -m)-$(nproc)cpu"

if [[ ! -f "$bench" ]]; then
    if [[ "$strict" == "1" ]]; then
        echo "check_bench: FAIL: $bench not found (STRICT=1)" >&2
        echo "check_bench: run: cargo bench --bench batching_bench -- --json" >&2
        exit 1
    fi
    echo "check_bench: WARN: $bench not found — nothing to gate (pass)" >&2
    echo "check_bench: run: cargo bench --bench batching_bench -- --json" >&2
    echo "check_bench: OK (skipped)"
    exit 0
fi

python3 - "$bench" "$min_speedup" "$host_machine" "$strict" <<'PY'
import json, sys

path, min_speedup, host_machine, strict = (
    sys.argv[1], float(sys.argv[2]), sys.argv[3], sys.argv[4] == "1")
with open(path) as f:
    bench = json.load(f)

rows = {int(r["batch"]): r for r in bench["rows"]}
fps1, fps8 = rows[1]["fps"], rows[8]["fps"]
speedup = fps8 / fps1
machine = bench.get("machine")
same_class = machine == host_machine
print(f"parity={bench['parity']}  fps@1={fps1:.0f}  fps@8={fps8:.0f}  "
      f"speedup={speedup:.2f}x (floor {min_speedup}x)  "
      f"machine={machine or 'unstamped'} vs host={host_machine}")

failed = False
# correctness claims travel with the artifact: fail on any machine
if bench["parity"] is not True:
    print("FAIL: batched execution is not bitwise identical to sequential", file=sys.stderr)
    failed = True
for r in bench["rows"]:
    if r["fps"] <= 0 or r["p99_ms"] <= 0:
        print(f"FAIL: degenerate row {r}", file=sys.stderr)
        failed = True
# perf claims only bind on the machine class that produced them
if speedup < min_speedup:
    if same_class or strict:
        print(f"FAIL: fps@8 is only {speedup:.2f}x fps@1 (< {min_speedup}x)", file=sys.stderr)
        failed = True
    else:
        print(f"WARN: fps@8 is only {speedup:.2f}x fps@1 (< {min_speedup}x), but the "
              f"artifact is from '{machine or 'unstamped'}', not this host — not gating",
              file=sys.stderr)

sys.exit(1 if failed else 0)
PY
echo "check_bench: OK"
