#!/usr/bin/env bash
# Perf-trend gate over the checked-in bench artifacts:
#   BENCH_batching.json  (cargo bench --bench batching_bench -- --json)
#   BENCH_solver.json    (cargo bench --bench solver_bench   -- --json)
#   BENCH_hotpath.json   (cargo bench --bench hotpath_microbench -- --json)
# The artifact kind is picked by filename: *solver* routes to the solver
# gate, *hotpath* to the crypto (sealed-hop) gate, anything else to the
# batching gate.
#
# The gates are deliberately coarse — they fail only on order-of-magnitude
# wrongness, not run-to-run jitter.
#
# Batching gate:
#   1. parity must be true: the batched path is worthless the moment it
#      stops being bitwise identical to sequential execution;
#   2. frames/sec at B=8 must be at least MIN_SPEEDUP (default 1.2×) of
#      the batch-1 baseline: if coalescing stops paying for itself the
#      batching machinery has regressed into pure overhead.
#
# Solver gate:
#   1. cache_bitwise must be true everywhere: a cache hit that differs
#      from the cold solve it stands in for is corruption, not caching;
#   2. no row may have exhausted the node budget: the bounded search must
#      finish inside its own bound on these reference topologies;
#   3. the 256-resource incremental re-solve must be ≥ INCR_SPEEDUP
#      (default 5×) faster than the cold solve;
#   4. the 1024-resource cold solve must finish under MAX_COLD_MS
#      (default 5000 ms).
#
# Crypto (sealed-hop) gate — plus the compute-pool and packed-B lanes
# that live in the same hotpath artifact:
#   1. parity must be true: the dispatched AES-GCM path is worthless the
#      moment it stops being bitwise identical to the scalar reference;
#   2. every sealed-hop row must be ≥ MIN_CRYPTO_SPEEDUP (default 3.0×)
#      of the scalar baseline — but only when the artifact was produced
#      on an AES-NI machine ("aesni": true): without the instructions the
#      dispatched path IS the scalar path and the ratio is ~1 by design;
#   3. compute_pool.parity must be true: pooled dispatch that changes a
#      single output bit versus 1 worker is a broken kernel, not a pool;
#   4. compute_pool.speedup must be ≥ MIN_POOL_SPEEDUP (default 2.0×) of
#      the 1-worker GEMM row — but only when the producing machine had
#      at least 4 cores ("cores" in the lane): a 1-core host runs the
#      pooled path at ~1× by construction, same logic as the AES-NI rule;
#   5. packed_b.parity must be true and its rows non-degenerate: packed
#      panels exist to kill re-packing traffic, so their perf is logged
#      as a trend, but bitwise identity is a hard contract.
#
# Portability rules (so a checkout without a fresh bench run, or a
# laptop-generated artifact checked on CI, never fails spuriously):
#   - a missing artifact WARNS and passes (nothing to gate);
#   - wall-time/speedup floors are only enforced when the artifact's
#     "machine" stamp matches this host's class ($(uname -m)-$(nproc)cpu)
#     — perf numbers from different hardware are a trend, not a contract;
#   - correctness claims (parity, cache_bitwise, budget, degenerate rows)
#     FAIL regardless of machine: correctness travels with the artifact.
# STRICT=1 restores hard failure for both relaxations (CI perf lane).
#
# Usage: scripts/check_bench.sh [path/to/BENCH_*.json]
set -euo pipefail

bench="${1:-BENCH_batching.json}"
min_speedup="${MIN_SPEEDUP:-1.2}"
incr_speedup="${INCR_SPEEDUP:-5}"
max_cold_ms="${MAX_COLD_MS:-5000}"
min_crypto_speedup="${MIN_CRYPTO_SPEEDUP:-3.0}"
min_pool_speedup="${MIN_POOL_SPEEDUP:-2.0}"
strict="${STRICT:-0}"
host_machine="$(uname -m)-$(nproc)cpu"

case "$(basename "$bench")" in
    *solver*) kind="solver"; bench_cmd="cargo bench --bench solver_bench -- --json" ;;
    *hotpath*) kind="crypto"; bench_cmd="cargo bench --bench hotpath_microbench -- --json" ;;
    *) kind="batching"; bench_cmd="cargo bench --bench batching_bench -- --json" ;;
esac

if [[ ! -f "$bench" ]]; then
    if [[ "$strict" == "1" ]]; then
        echo "check_bench: FAIL: $bench not found (STRICT=1)" >&2
        echo "check_bench: run: $bench_cmd" >&2
        exit 1
    fi
    echo "check_bench: WARN: $bench not found — nothing to gate (pass)" >&2
    echo "check_bench: run: $bench_cmd" >&2
    echo "check_bench: OK (skipped)"
    exit 0
fi

if [[ "$kind" == "crypto" ]]; then
python3 - "$bench" "$min_crypto_speedup" "$host_machine" "$strict" "$min_pool_speedup" <<'PY'
import json, sys

path, min_speedup, host_machine, strict = (
    sys.argv[1], float(sys.argv[2]), sys.argv[3], sys.argv[4] == "1")
min_pool_speedup = float(sys.argv[5])
with open(path) as f:
    bench = json.load(f)

hop = bench.get("sealed_hop")
if hop is None:
    print("FAIL: no sealed_hop lane in the artifact (stale bench run?)",
          file=sys.stderr)
    sys.exit(1)
machine = bench.get("machine")
same_class = machine == host_machine
aesni = hop.get("aesni") is True
gate = (same_class or strict) and aesni
for r in hop["rows"]:
    print(f"sealed hop {r['payload']:>7}: dispatched={r['dispatched_gbps']:.2f} GB/s "
          f"scalar={r['scalar_gbps']:.2f} GB/s speedup={r['speedup']:.2f}x")
print(f"parity={hop['parity']}  aesni={aesni}  "
      f"machine={machine or 'unstamped'} vs host={host_machine} "
      f"(speedup floor {min_speedup}x {'enforced' if gate else 'advisory'})")

failed = False
# correctness claims travel with the artifact: fail on any machine
if hop["parity"] is not True:
    print("FAIL: dispatched GCM is not bitwise identical to scalar",
          file=sys.stderr)
    failed = True
for r in hop["rows"]:
    if r["dispatched_gbps"] <= 0 or r["scalar_gbps"] <= 0:
        print(f"FAIL: degenerate row {r}", file=sys.stderr)
        failed = True
    # the speedup floor binds only on the producing machine class (or
    # STRICT=1), and only when that machine has AES-NI at all
    elif r["speedup"] < min_speedup:
        if gate:
            print(f"FAIL: sealed hop {r['payload']} is only "
                  f"{r['speedup']:.2f}x scalar (< {min_speedup}x)",
                  file=sys.stderr)
            failed = True
        else:
            why = ("no AES-NI on the producing machine" if not aesni else
                   f"artifact is from '{machine or 'unstamped'}', not this host")
            print(f"WARN: sealed hop {r['payload']} is only "
                  f"{r['speedup']:.2f}x scalar (< {min_speedup}x), but "
                  f"{why} — not gating", file=sys.stderr)

# --- compute-pool lane: pooled dispatch vs the 1-worker GEMM row -------
pool = bench.get("compute_pool")
if pool is None:
    print("FAIL: no compute_pool lane in the artifact (stale bench run?)",
          file=sys.stderr)
    failed = True
else:
    cores = int(pool.get("cores", 0))
    pool_gate = (same_class or strict) and cores >= 4
    print(f"compute pool: {pool['speedup']:.2f}x at {int(pool['workers'])} "
          f"workers (cores={cores} parity={pool['parity']}, floor "
          f"{min_pool_speedup}x {'enforced' if pool_gate else 'advisory'})")
    if pool["parity"] is not True:
        print("FAIL: pooled dispatch is not bitwise identical to 1 worker",
              file=sys.stderr)
        failed = True
    if pool["gemm_1w_ns"] <= 0 or pool["pooled_ns"] <= 0:
        print(f"FAIL: degenerate compute_pool lane {pool}", file=sys.stderr)
        failed = True
    # the floor binds only where there are cores to scale across (the
    # producing machine class, or STRICT, with >= 4 cores) — a 1-core
    # host runs the pooled path at ~1x by construction
    elif pool["speedup"] < min_pool_speedup:
        if pool_gate:
            print(f"FAIL: pooled conv is only {pool['speedup']:.2f}x the "
                  f"1-worker row (< {min_pool_speedup}x)", file=sys.stderr)
            failed = True
        else:
            why = (f"only {cores} core(s) on the producing machine"
                   if cores < 4 else
                   f"artifact is from '{machine or 'unstamped'}', not this host")
            print(f"WARN: pooled conv is only {pool['speedup']:.2f}x the "
                  f"1-worker row (< {min_pool_speedup}x), but {why} — "
                  f"not gating", file=sys.stderr)

# --- packed-B lane: prepacked weight panels vs the pack-free path ------
packed = bench.get("packed_b")
if packed is None:
    print("FAIL: no packed_b lane in the artifact (stale bench run?)",
          file=sys.stderr)
    failed = True
else:
    for r in packed["rows"]:
        print(f"packed-B {r['component']:>8}: unpacked={r['unpacked_ns']:.0f}ns "
              f"packed={r['packed_ns']:.0f}ns speedup={r['speedup']:.2f}x")
    print(f"packed-B parity={packed['parity']} (perf is a logged trend, "
          f"parity is the contract)")
    if packed["parity"] is not True:
        print("FAIL: packed-B path is not bitwise identical to unpacked",
              file=sys.stderr)
        failed = True
    for r in packed["rows"]:
        if r["unpacked_ns"] <= 0 or r["packed_ns"] <= 0:
            print(f"FAIL: degenerate packed_b row {r}", file=sys.stderr)
            failed = True

sys.exit(1 if failed else 0)
PY
elif [[ "$kind" == "solver" ]]; then
python3 - "$bench" "$incr_speedup" "$max_cold_ms" "$host_machine" "$strict" <<'PY'
import json, sys

path, incr_speedup, max_cold_ms, host_machine, strict = (
    sys.argv[1], float(sys.argv[2]), float(sys.argv[3]), sys.argv[4],
    sys.argv[5] == "1")
with open(path) as f:
    bench = json.load(f)

rows = {int(r["resources"]): r for r in bench["rows"]}
machine = bench.get("machine")
same_class = machine == host_machine
gate = same_class or strict
for r in bench["rows"]:
    print(f"{r['topology']:>10}  r={r['resources']:<5} mode={r['mode']:<6} "
          f"cold={r['cold_ms']:.2f}ms incr={r['incr_ms']:.2f}ms "
          f"speedup={r['speedup']:.1f}x cache_bitwise={r['cache_bitwise']}")
print(f"machine={machine or 'unstamped'} vs host={host_machine} "
      f"(perf floors {'enforced' if gate else 'advisory'})")

failed = False
# correctness claims travel with the artifact: fail on any machine
if bench.get("cache_bitwise") is not True:
    print("FAIL: a cache hit differed from its cold solve", file=sys.stderr)
    failed = True
for r in bench["rows"]:
    if r["cold_ms"] <= 0 or r["incr_ms"] <= 0:
        print(f"FAIL: degenerate row {r}", file=sys.stderr)
        failed = True
    if r.get("budget_exhausted"):
        print(f"FAIL: {r['topology']} exhausted the node budget", file=sys.stderr)
        failed = True
# perf claims only bind on the machine class that produced them
checks = []
if 256 in rows:
    r = rows[256]
    checks.append((r["speedup"] >= incr_speedup,
                   f"incremental re-solve at 256 is only {r['speedup']:.1f}x "
                   f"cold (< {incr_speedup}x)"))
else:
    print("FAIL: no 256-resource row", file=sys.stderr)
    failed = True
if 1024 in rows:
    r = rows[1024]
    checks.append((r["cold_ms"] < max_cold_ms,
                   f"cold solve at 1024 took {r['cold_ms']:.0f}ms "
                   f"(>= {max_cold_ms:.0f}ms)"))
else:
    print("FAIL: no 1024-resource row", file=sys.stderr)
    failed = True
for ok, msg in checks:
    if ok:
        continue
    if gate:
        print(f"FAIL: {msg}", file=sys.stderr)
        failed = True
    else:
        print(f"WARN: {msg}, but the artifact is from "
              f"'{machine or 'unstamped'}', not this host — not gating",
              file=sys.stderr)

sys.exit(1 if failed else 0)
PY
else
python3 - "$bench" "$min_speedup" "$host_machine" "$strict" <<'PY'
import json, sys

path, min_speedup, host_machine, strict = (
    sys.argv[1], float(sys.argv[2]), sys.argv[3], sys.argv[4] == "1")
with open(path) as f:
    bench = json.load(f)

rows = {int(r["batch"]): r for r in bench["rows"]}
fps1, fps8 = rows[1]["fps"], rows[8]["fps"]
speedup = fps8 / fps1
machine = bench.get("machine")
same_class = machine == host_machine
print(f"parity={bench['parity']}  fps@1={fps1:.0f}  fps@8={fps8:.0f}  "
      f"speedup={speedup:.2f}x (floor {min_speedup}x)  "
      f"machine={machine or 'unstamped'} vs host={host_machine}")

failed = False
# correctness claims travel with the artifact: fail on any machine
if bench["parity"] is not True:
    print("FAIL: batched execution is not bitwise identical to sequential", file=sys.stderr)
    failed = True
for r in bench["rows"]:
    if r["fps"] <= 0 or r["p99_ms"] <= 0:
        print(f"FAIL: degenerate row {r}", file=sys.stderr)
        failed = True
# perf claims only bind on the machine class that produced them
if speedup < min_speedup:
    if same_class or strict:
        print(f"FAIL: fps@8 is only {speedup:.2f}x fps@1 (< {min_speedup}x)", file=sys.stderr)
        failed = True
    else:
        print(f"WARN: fps@8 is only {speedup:.2f}x fps@1 (< {min_speedup}x), but the "
              f"artifact is from '{machine or 'unstamped'}', not this host — not gating",
              file=sys.stderr)

sys.exit(1 if failed else 0)
PY
fi
echo "check_bench: OK"
