#!/usr/bin/env bash
# Perf-trend gate over BENCH_batching.json (written by
# `cargo bench --bench batching_bench -- --json`).
#
# The gate is deliberately coarse — it fails only on order-of-magnitude
# wrongness, not run-to-run jitter:
#   1. parity must be true: the batched path is worthless the moment it
#      stops being bitwise identical to sequential execution;
#   2. frames/sec at B=8 must be at least MIN_SPEEDUP (default 1.2×) of
#      the batch-1 baseline: if coalescing stops paying for itself the
#      batching machinery has regressed into pure overhead.
#
# Usage: scripts/check_bench.sh [path/to/BENCH_batching.json]
set -euo pipefail

bench="${1:-BENCH_batching.json}"
min_speedup="${MIN_SPEEDUP:-1.2}"

if [[ ! -f "$bench" ]]; then
    echo "check_bench: $bench not found (run: cargo bench --bench batching_bench -- --json)" >&2
    exit 1
fi

python3 - "$bench" "$min_speedup" <<'PY'
import json, sys

path, min_speedup = sys.argv[1], float(sys.argv[2])
with open(path) as f:
    bench = json.load(f)

rows = {int(r["batch"]): r for r in bench["rows"]}
fps1, fps8 = rows[1]["fps"], rows[8]["fps"]
speedup = fps8 / fps1
print(f"parity={bench['parity']}  fps@1={fps1:.0f}  fps@8={fps8:.0f}  "
      f"speedup={speedup:.2f}x (floor {min_speedup}x)")

failed = False
if bench["parity"] is not True:
    print("FAIL: batched execution is not bitwise identical to sequential", file=sys.stderr)
    failed = True
if speedup < min_speedup:
    print(f"FAIL: fps@8 is only {speedup:.2f}x fps@1 (< {min_speedup}x)", file=sys.stderr)
    failed = True
for r in bench["rows"]:
    if r["fps"] <= 0 or r["p99_ms"] <= 0:
        print(f"FAIL: degenerate row {r}", file=sys.stderr)
        failed = True

sys.exit(1 if failed else 0)
PY
echo "check_bench: OK"
