#!/usr/bin/env bash
# Unit tests for scripts/check_bench.sh: exercises every gate/warn path
# against synthetic batching/solver/crypto artifacts in a temp dir. Run
# directly (CI runs it next to the real gate):
#
#   scripts/test_check_bench.sh
#
# Contract under test:
#   - missing artifact        → warn + pass   (STRICT=1 → fail)
#   - parity=false            → fail on ANY machine class
#   - degenerate rows         → fail on ANY machine class
#   - speedup below floor     → fail only on the producing machine class
#                               (different/unstamped class → warn + pass;
#                                STRICT=1 → fail regardless)
set -uo pipefail

here="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
check="$here/check_bench.sh"
tmp="$(mktemp -d)"
trap 'rm -rf "$tmp"' EXIT

host="$(uname -m)-$(nproc)cpu"
pass=0
fail=0

# mk <file> <parity:true|false> <fps8> <machine|none>
# (fps@1 is fixed at 1000, so fps8 sets the speedup directly)
mk() {
    python3 - "$1" "$2" "$3" "$4" <<'PY'
import json, sys
file, parity, fps8, machine = (
    sys.argv[1], sys.argv[2] == "true", float(sys.argv[3]), sys.argv[4])
doc = {
    "bench": "batching_bench",
    "parity": parity,
    "rows": [
        {"batch": 1, "fps": 1000.0, "p99_ms": 1.0, "mean_ms": 0.5},
        {"batch": 8, "fps": fps8, "p99_ms": 1.0, "mean_ms": 0.5},
    ],
}
if machine != "none":
    doc["machine"] = machine
with open(file, "w") as f:
    json.dump(doc, f)
PY
}

# expect <name> <want_rc> <got_rc>
expect() {
    if [[ "$3" == "$2" ]]; then
        echo "ok   $1"
        pass=$((pass + 1))
    else
        echo "FAIL $1: want exit $2, got $3"
        fail=$((fail + 1))
    fi
}

# missing artifact: nothing to gate → pass; STRICT makes it binding
rc=0; "$check" "$tmp/absent.json" >/dev/null 2>&1 || rc=$?
expect "missing artifact warns and passes" 0 "$rc"
rc=0; STRICT=1 "$check" "$tmp/absent.json" >/dev/null 2>&1 || rc=$?
expect "missing artifact fails under STRICT=1" 1 "$rc"

# healthy artifact from this machine class
mk "$tmp/good.json" true 1500 "$host"
rc=0; "$check" "$tmp/good.json" >/dev/null 2>&1 || rc=$?
expect "healthy same-class artifact passes" 0 "$rc"

# healthy but unstamped (pre-machine-field artifact)
mk "$tmp/good_unstamped.json" true 1500 none
rc=0; "$check" "$tmp/good_unstamped.json" >/dev/null 2>&1 || rc=$?
expect "healthy unstamped artifact passes" 0 "$rc"

# parity break: correctness travels with the artifact — fails even from
# a foreign machine class
mk "$tmp/parity.json" false 1500 "other-0cpu"
rc=0; "$check" "$tmp/parity.json" >/dev/null 2>&1 || rc=$?
expect "parity=false fails on any machine class" 1 "$rc"

# degenerate row: also machine-independent
mk "$tmp/degenerate.json" true 0 "other-0cpu"
rc=0; "$check" "$tmp/degenerate.json" >/dev/null 2>&1 || rc=$?
expect "degenerate row fails on any machine class" 1 "$rc"

# speedup shortfall: binds only on the producing class
mk "$tmp/slow_same.json" true 1100 "$host"
rc=0; "$check" "$tmp/slow_same.json" >/dev/null 2>&1 || rc=$?
expect "speedup shortfall fails on the same class" 1 "$rc"

mk "$tmp/slow_other.json" true 1100 "other-0cpu"
rc=0; "$check" "$tmp/slow_other.json" >/dev/null 2>&1 || rc=$?
expect "speedup shortfall warns and passes cross-class" 0 "$rc"
out="$("$check" "$tmp/slow_other.json" 2>&1)" || true
case "$out" in
    *WARN*) expect "cross-class shortfall prints a WARN" 0 0 ;;
    *) expect "cross-class shortfall prints a WARN" 0 1 ;;
esac

mk "$tmp/slow_unstamped.json" true 1100 none
rc=0; "$check" "$tmp/slow_unstamped.json" >/dev/null 2>&1 || rc=$?
expect "speedup shortfall passes when unstamped" 0 "$rc"

rc=0; STRICT=1 "$check" "$tmp/slow_other.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 restores the hard speedup gate" 1 "$rc"

# the floor itself stays tunable
rc=0; MIN_SPEEDUP=1.05 "$check" "$tmp/slow_same.json" >/dev/null 2>&1 || rc=$?
expect "MIN_SPEEDUP lowers the floor" 0 "$rc"

# ---- solver gate (filenames containing "solver" route here) -----------------

# mk_solver <file> <bitwise:true|false> <speedup256> <cold1024_ms> <machine|none>
mk_solver() {
    python3 - "$1" "$2" "$3" "$4" "$5" <<'PY'
import json, sys
file, bitwise, sp256, cold1024, machine = (
    sys.argv[1], sys.argv[2] == "true", float(sys.argv[3]), float(sys.argv[4]),
    sys.argv[5])
def row(topo, n, mode, cold, incr):
    return {"topology": topo, "resources": n, "mode": mode, "nodes": 100,
            "budget_exhausted": False, "cold_ms": cold, "incr_ms": incr,
            "speedup": cold / incr, "cache_hit": True, "cache_bitwise": bitwise,
            "spliced": True}
doc = {
    "bench": "solver_bench",
    "cache_bitwise": bitwise,
    "rows": [
        row("paper-5", 5, "exact", 1.0, 1.0),
        row("tree-64", 64, "beam", 20.0, 4.0),
        row("tree-256", 256, "beam", sp256 * 10.0, 10.0),
        row("rand-1024", 1024, "beam", cold1024, 30.0),
    ],
}
if machine != "none":
    doc["machine"] = machine
with open(file, "w") as f:
    json.dump(doc, f)
PY
}

mk_solver "$tmp/solver_good.json" true 8 900 "$host"
rc=0; "$check" "$tmp/solver_good.json" >/dev/null 2>&1 || rc=$?
expect "healthy solver artifact passes" 0 "$rc"

mk_solver "$tmp/solver_bitwise.json" false 8 900 "other-0cpu"
rc=0; "$check" "$tmp/solver_bitwise.json" >/dev/null 2>&1 || rc=$?
expect "cache_bitwise=false fails on any machine class" 1 "$rc"

mk_solver "$tmp/solver_slow_incr.json" true 2 900 "$host"
rc=0; "$check" "$tmp/solver_slow_incr.json" >/dev/null 2>&1 || rc=$?
expect "incremental shortfall at 256 fails on the same class" 1 "$rc"

mk_solver "$tmp/solver_slow_other.json" true 2 900 "other-0cpu"
rc=0; "$check" "$tmp/solver_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "incremental shortfall warns and passes cross-class" 0 "$rc"

rc=0; STRICT=1 "$check" "$tmp/solver_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 restores the hard incremental gate" 1 "$rc"

mk_solver "$tmp/solver_slow_cold.json" true 8 9000 "$host"
rc=0; "$check" "$tmp/solver_slow_cold.json" >/dev/null 2>&1 || rc=$?
expect "cold solve over 5s at 1024 fails on the same class" 1 "$rc"

# ---- crypto gate (filenames containing "hotpath" route here) ----------------

# mk_crypto <file> <parity:true|false> <aesni:true|false> <speedup> <machine|none>
#           [pool_parity] [pool_speedup] [cores] [packed_parity] [omit-lane]
# The trailing args default to a healthy compute_pool/packed_b pair
# (parity true, 3.0x at 4 cores); "omit-lane" of "nopool"/"nopacked"
# drops that lane entirely (stale-artifact case).
mk_crypto() {
    python3 - "$1" "$2" "$3" "$4" "$5" "${6:-true}" "${7:-3.0}" "${8:-4}" \
        "${9:-true}" "${10:-none}" <<'PY'
import json, sys
file, parity, aesni, speedup, machine = (
    sys.argv[1], sys.argv[2] == "true", sys.argv[3] == "true",
    float(sys.argv[4]), sys.argv[5])
pool_parity, pool_speedup, cores = (
    sys.argv[6] == "true", float(sys.argv[7]), int(sys.argv[8]))
packed_parity, omit = sys.argv[9] == "true", sys.argv[10]
def row(payload, nbytes):
    scalar = 0.8
    return {"payload": payload, "bytes": nbytes,
            "dispatched_gbps": scalar * speedup, "scalar_gbps": scalar,
            "speedup": speedup}
doc = {
    "bench": "hotpath_microbench",
    "rows": [],
    "sealed_hop": {
        "aesni": aesni,
        "parity": parity,
        "rows": [row("64 KiB", 65536), row("1 MiB", 1048576)],
    },
    "compute_pool": {
        "cores": cores, "workers": 4, "parity": pool_parity,
        "gemm_1w_ns": 1000000.0,
        "pooled_ns": 1000000.0 / pool_speedup,
        "speedup": pool_speedup,
    },
    "packed_b": {
        "parity": packed_parity,
        "rows": [
            {"component": "conv3x3", "unpacked_ns": 1000000.0,
             "packed_ns": 900000.0, "speedup": 1.11},
            {"component": "dense", "unpacked_ns": 300000.0,
             "packed_ns": 280000.0, "speedup": 1.07},
        ],
    },
}
if omit == "nopool":
    del doc["compute_pool"]
elif omit == "nopacked":
    del doc["packed_b"]
if machine != "none":
    doc["machine"] = machine
with open(file, "w") as f:
    json.dump(doc, f)
PY
}

mk_crypto "$tmp/hotpath_good.json" true true 4.0 "$host"
rc=0; "$check" "$tmp/hotpath_good.json" >/dev/null 2>&1 || rc=$?
expect "healthy crypto artifact passes" 0 "$rc"

mk_crypto "$tmp/hotpath_parity.json" false true 4.0 "other-0cpu"
rc=0; "$check" "$tmp/hotpath_parity.json" >/dev/null 2>&1 || rc=$?
expect "crypto parity=false fails on any machine class" 1 "$rc"

mk_crypto "$tmp/hotpath_slow_same.json" true true 1.5 "$host"
rc=0; "$check" "$tmp/hotpath_slow_same.json" >/dev/null 2>&1 || rc=$?
expect "crypto speedup shortfall fails on the same AES-NI class" 1 "$rc"

mk_crypto "$tmp/hotpath_slow_other.json" true true 1.5 "other-0cpu"
rc=0; "$check" "$tmp/hotpath_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "crypto speedup shortfall warns and passes cross-class" 0 "$rc"

rc=0; STRICT=1 "$check" "$tmp/hotpath_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 restores the hard crypto speedup gate" 1 "$rc"

# no AES-NI on the producer: dispatched == scalar by design, the floor
# must never bind — not even under STRICT (there is nothing to speed up)
mk_crypto "$tmp/hotpath_noaesni.json" true false 1.0 "$host"
rc=0; "$check" "$tmp/hotpath_noaesni.json" >/dev/null 2>&1 || rc=$?
expect "speedup ~1 passes on a machine without AES-NI" 0 "$rc"
rc=0; STRICT=1 "$check" "$tmp/hotpath_noaesni.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 still passes without AES-NI" 0 "$rc"

# a parity break without AES-NI is still a correctness failure
mk_crypto "$tmp/hotpath_noaesni_parity.json" false false 1.0 "$host"
rc=0; "$check" "$tmp/hotpath_noaesni_parity.json" >/dev/null 2>&1 || rc=$?
expect "parity=false fails even without AES-NI" 1 "$rc"

rc=0; MIN_CRYPTO_SPEEDUP=1.2 "$check" "$tmp/hotpath_slow_same.json" >/dev/null 2>&1 || rc=$?
expect "MIN_CRYPTO_SPEEDUP lowers the crypto floor" 0 "$rc"

# ---- compute-pool lane (same hotpath artifact) -------------------------------

# pooled dispatch that differs bitwise from 1 worker is corruption
mk_crypto "$tmp/hotpath_pool_parity.json" true true 4.0 "other-0cpu" false
rc=0; "$check" "$tmp/hotpath_pool_parity.json" >/dev/null 2>&1 || rc=$?
expect "pool parity=false fails on any machine class" 1 "$rc"

# pool speedup shortfall with >= 4 cores binds on the producing class
mk_crypto "$tmp/hotpath_pool_slow.json" true true 4.0 "$host" true 1.3 4
rc=0; "$check" "$tmp/hotpath_pool_slow.json" >/dev/null 2>&1 || rc=$?
expect "pool shortfall fails on the same 4-core class" 1 "$rc"

mk_crypto "$tmp/hotpath_pool_slow_other.json" true true 4.0 "other-0cpu" true 1.3 4
rc=0; "$check" "$tmp/hotpath_pool_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "pool shortfall warns and passes cross-class" 0 "$rc"
rc=0; STRICT=1 "$check" "$tmp/hotpath_pool_slow_other.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 restores the hard pool speedup gate" 1 "$rc"

# a 1-core producer cannot scale: floor never binds, not even STRICT
mk_crypto "$tmp/hotpath_pool_1core.json" true true 4.0 "$host" true 1.0 1
rc=0; "$check" "$tmp/hotpath_pool_1core.json" >/dev/null 2>&1 || rc=$?
expect "pool speedup ~1 passes on a 1-core producer" 0 "$rc"
rc=0; STRICT=1 "$check" "$tmp/hotpath_pool_1core.json" >/dev/null 2>&1 || rc=$?
expect "STRICT=1 still passes on a 1-core producer" 0 "$rc"

# ...but pool parity is still a hard contract on 1 core
mk_crypto "$tmp/hotpath_pool_1core_parity.json" true true 4.0 "$host" false 1.0 1
rc=0; "$check" "$tmp/hotpath_pool_1core_parity.json" >/dev/null 2>&1 || rc=$?
expect "pool parity=false fails even on a 1-core producer" 1 "$rc"

rc=0; MIN_POOL_SPEEDUP=1.2 "$check" "$tmp/hotpath_pool_slow.json" >/dev/null 2>&1 || rc=$?
expect "MIN_POOL_SPEEDUP lowers the pool floor" 0 "$rc"

# a hotpath artifact without the lane predates this gate: stale, rerun
mk_crypto "$tmp/hotpath_nopool.json" true true 4.0 "$host" true 3.0 4 true nopool
rc=0; "$check" "$tmp/hotpath_nopool.json" >/dev/null 2>&1 || rc=$?
expect "missing compute_pool lane fails as stale" 1 "$rc"

# ---- packed-B lane (same hotpath artifact) -----------------------------------

mk_crypto "$tmp/hotpath_packed_parity.json" true true 4.0 "other-0cpu" true 3.0 4 false
rc=0; "$check" "$tmp/hotpath_packed_parity.json" >/dev/null 2>&1 || rc=$?
expect "packed-B parity=false fails on any machine class" 1 "$rc"

mk_crypto "$tmp/hotpath_nopacked.json" true true 4.0 "$host" true 3.0 4 true nopacked
rc=0; "$check" "$tmp/hotpath_nopacked.json" >/dev/null 2>&1 || rc=$?
expect "missing packed_b lane fails as stale" 1 "$rc"

echo
echo "test_check_bench: $pass passed, $fail failed"
[[ "$fail" == "0" ]]
